"""Supervision layer: worker LIFECYCLE decoupled from worker TRANSPORT.

PR 3's ``RemoteRolloutHost`` conflated two orthogonal questions — *how a
worker comes to exist* and *how it is supervised* — into one Service with
a bespoke monitor thread, which locked the system into exactly one
lifecycle (parent-spawned child whose death fails the run). This module
splits them:

  * :class:`WorkerEndpoint` answers the first question for ONE incarnation
    of a worker. :class:`SpawnedEndpoint` is the PR 3 lifecycle (a
    ``spawn``-start-method child process; liveness = the process object);
    :class:`ConnectedEndpoint` is the multi-host lifecycle (a worker
    started elsewhere — ``python -m repro.launch.worker`` — dials the
    :class:`~repro.runtime.transport.server.TransportServer`, authenticates
    with the shared token, and receives its spec; liveness = the heartbeat
    report stream).

  * :class:`Supervisor` answers the second. It is ONE service owning N
    :class:`SupervisedWorker` slots; its thread runs the shared state
    machine (launch → up → failure → backoff → relaunch | FAILED) under a
    declarative :class:`RestartPolicy`. ``never`` reproduces PR 3 exactly
    (any failure marks the slot FAILED and schedulers fail fast);
    ``on_failure`` respawns (spawn mode) or re-opens the slot for a redial
    (connect mode) with exponential backoff, up to ``max_restarts`` within
    a sliding ``window_s`` — exhausting the budget surfaces FAILED with
    the same fail-fast behavior.

Each relaunch/re-accept begins a new *incarnation*: the slot's bridged
:class:`~repro.runtime.service.MetricsRegistry` folds the dead
incarnation's counters into a monotone base (``begin_remote_incarnation``)
so ``metrics()["services"]`` keeps ONE coherent, monotonically-counting
entry per worker across restarts, and stale-incarnation reports are
dropped (and answered with ``stop``) rather than corrupting the bridge.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import threading
import time
from typing import Dict, List, Optional

from repro.runtime.service import Service, ServiceState
from repro.runtime.transport.remote import (RemoteWorkerSpec, _child_entry,
                                            spec_to_wire)

__all__ = ["RestartPolicy", "ElasticPolicy", "WorkerEndpoint",
           "SpawnedEndpoint", "ConnectedEndpoint", "SupervisedWorker",
           "Supervisor"]

RESTART_MODES = ("never", "on_failure")


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Declarative restart semantics for a supervised worker slot.

    ``never`` — any failure is terminal (PR 3 parity). ``on_failure`` —
    up to ``max_restarts`` relaunches within a sliding ``window_s``;
    restarts outside the window stop counting against the budget, so a
    long-lived worker that crashes once a day never exhausts it. Backoff
    before the k-th restart in the window is
    ``backoff_initial_s * backoff_factor**(k-1)`` capped at
    ``backoff_max_s``."""

    mode: str = "never"
    max_restarts: int = 2
    window_s: float = 60.0
    backoff_initial_s: float = 0.1
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0

    def __post_init__(self):
        if self.mode not in RESTART_MODES:
            raise ValueError(f"restart mode {self.mode!r} not in "
                             f"{RESTART_MODES}")

    def backoff_s(self, restarts_in_window: int) -> float:
        return min(self.backoff_initial_s
                   * self.backoff_factor ** max(restarts_in_window - 1, 0),
                   self.backoff_max_s)


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    """Declarative autoscaling for the supervisor's worker fleet.

    Signals come from a caller-supplied ``signal_fn`` (the orchestrator
    derives them from state already in ``metrics()["services"]``):

      * ``depth_frac`` — experience-queue depth / capacity. Near 0 the
        trainer is starving (pops outrun puts): scale UP. Above
        ``scale_down_depth`` producers are outrunning the trainer and
        extra workers only feed the drop policy: scale DOWN.
      * ``staleness`` — published weight version minus the oldest policy
        version any live worker is acting on. Beyond ``staleness_cap``
        the fleet is too large for the publish cadence (more workers =
        more off-policy lag), so it also gates scale-up and forces
        scale-down.
      * ``infer_queue_depth`` / ``infer_window_fill`` — inference-tier
        pressure (the pool's own autoscaling gauges). A tier at/above
        ``tier_queue_hot`` requests or ``tier_fill_hot`` window fill is
        *saturated*: demand is outrunning serving capacity, so the
        autoscaler treats it as an additional scale-up trigger and never
        scales down while it persists (either threshold at 0 disables
        that signal).

    Scale-down never kills a worker mid-flight: the slot enters a
    ``draining`` phase — the stop flag rides the next report reply, the
    worker body stops its services and ``close()``s its channels (which
    flushes the PutStream window), and only when the endpoint observes
    the exit (or ``drain_timeout_s`` lapses) is the slot retired. A
    drained slot is NOT a failure: no restart budget is charged and no
    error is surfaced to schedulers."""

    min_workers: int = 1
    max_workers: int = 4
    interval_s: float = 2.0        # cooldown between scaling decisions
    scale_up_depth: float = 0.25   # depth_frac at/below → scale up
    scale_down_depth: float = 0.9  # depth_frac at/above → scale down
    staleness_cap: float = 0.0     # 0 = staleness signal unused
    tier_queue_hot: float = 0.0    # infer queue depth at/above → saturated
    tier_fill_hot: float = 0.0     # infer window fill at/above → saturated
    drain_timeout_s: float = 10.0

    def __post_init__(self):
        if self.min_workers < 0 or self.max_workers < self.min_workers:
            raise ValueError(
                f"need 0 <= min_workers <= max_workers, got "
                f"{self.min_workers}..{self.max_workers}")
        if not 0.0 <= self.scale_up_depth < self.scale_down_depth <= 1.0:
            raise ValueError(
                f"need 0 <= scale_up_depth < scale_down_depth <= 1, got "
                f"{self.scale_up_depth}/{self.scale_down_depth}")
        if self.tier_queue_hot < 0:
            raise ValueError(
                f"tier_queue_hot must be >= 0, got {self.tier_queue_hot}")
        if not 0.0 <= self.tier_fill_hot <= 1.0:
            raise ValueError(
                f"tier_fill_hot must be in [0, 1], got "
                f"{self.tier_fill_hot}")


# ---------------------------------------------------------------------------
# endpoints: how one incarnation of a worker comes to exist
# ---------------------------------------------------------------------------

class WorkerEndpoint:
    """One incarnation's existence + liveness. Stateless about policy —
    restarts, budgets, and backoff belong to the :class:`Supervisor`."""

    mode = "abstract"

    def launch(self, spec: RemoteWorkerSpec) -> None:
        """Begin an incarnation (spawn a child / open the slot for a
        dial-in)."""
        raise NotImplementedError

    def failure(self) -> Optional[str]:
        """Why the current incarnation is dead, or None while it lives
        (a connect slot still waiting inside its attach window is alive)."""
        raise NotImplementedError

    def note_report(self) -> None:
        """A heartbeat report from the current incarnation arrived."""

    def shutdown(self, timeout: float = 5.0) -> None:
        """Reap the incarnation if this side owns it (terminate → kill for
        a spawned child; nothing to do for a dialed-in peer — the stop
        flag in its report replies is the only lever)."""


class SpawnedEndpoint(WorkerEndpoint):
    """PR 3's lifecycle: the worker is a child process of this host."""

    mode = "spawn"

    def __init__(self):
        self.process: Optional[multiprocessing.process.BaseProcess] = None

    def launch(self, spec: RemoteWorkerSpec) -> None:
        ctx = multiprocessing.get_context("spawn")
        self.process = ctx.Process(target=_child_entry, args=(spec,),
                                   name=spec.name, daemon=True)
        self.process.start()

    def failure(self) -> Optional[str]:
        if self.process is None:
            return "never launched"
        if self.process.is_alive():
            return None
        return f"process died (exitcode={self.process.exitcode})"

    def shutdown(self, timeout: float = 5.0) -> None:
        proc = self.process
        if proc is None:
            return
        proc.join(timeout=timeout)
        if proc.is_alive():
            proc.terminate()
            proc.join(timeout=2.0)
        if proc.is_alive():                # pragma: no cover — last resort
            proc.kill()
            proc.join(timeout=2.0)


class ConnectedEndpoint(WorkerEndpoint):
    """Multi-host lifecycle: the worker lives elsewhere and dials in.

    ``launch`` only opens the slot (arms the attach window); the
    :class:`Supervisor`'s hello handler calls :meth:`attach` when a worker
    completes the token handshake. Liveness afterwards is the heartbeat
    stream: a report gap beyond ``liveness_timeout_s`` is this lifecycle's
    equivalent of a dead process (the peer may be SIGKILLed, partitioned,
    or wedged — indistinguishable from here, all handled by re-accepting
    a redial under the restart budget)."""

    mode = "connect"

    def __init__(self, *, liveness_timeout_s: float,
                 attach_timeout_s: float):
        self.liveness_timeout_s = liveness_timeout_s
        self.attach_timeout_s = attach_timeout_s
        self.attached_incarnation: Optional[int] = None
        self.last_report_t: Optional[float] = None
        self._opened_t: Optional[float] = None

    def launch(self, spec: RemoteWorkerSpec) -> None:
        self._opened_t = time.monotonic()
        self.attached_incarnation = None
        self.last_report_t = None

    def attach(self, incarnation: int) -> None:
        self.attached_incarnation = incarnation
        self.last_report_t = time.monotonic()

    def note_report(self) -> None:
        self.last_report_t = time.monotonic()

    def failure(self) -> Optional[str]:
        now = time.monotonic()
        if self.attached_incarnation is None:
            if (self._opened_t is not None
                    and now - self._opened_t > self.attach_timeout_s):
                return (f"no worker dialed in within "
                        f"{self.attach_timeout_s:.1f}s")
            return None                    # still inside the attach window
        if (self.last_report_t is not None
                and now - self.last_report_t > self.liveness_timeout_s):
            return (f"report stream stalled for more than "
                    f"{self.liveness_timeout_s:.1f}s (worker died or "
                    f"partitioned)")
        return None


# ---------------------------------------------------------------------------
# the supervised slot: one bus entry per worker, stable across incarnations
# ---------------------------------------------------------------------------

class SupervisedWorker(Service):
    """Passive Service (no thread of its own): the per-worker entry on the
    bus. It carries the slot's identity (`name`), the bridged metrics
    registry, and the report sink across every incarnation the Supervisor
    runs through its endpoint — so ``metrics()["services"]`` shows a
    single coherent worker entry no matter how many times the underlying
    process was replaced."""

    def __init__(self, spec: RemoteWorkerSpec, endpoint: WorkerEndpoint,
                 server, *, role: str = "rollout"):
        super().__init__(spec.name, role=role)
        self.spec = spec
        self.endpoint = endpoint
        self.server = server
        server.register_worker_sink(spec.name, self)
        self.lock = threading.Lock()
        self.incarnation = 0               # 0 = nothing launched yet
        self.restarts = 0
        self.phase = "new"         # new|up|waiting|backoff|draining|done
        self.relaunch_at = 0.0
        # elastic bookkeeping: True for slots the autoscaler added (only
        # those are eligible for scale-down), drain_deadline bounds how
        # long a draining worker may take to flush and exit
        self.elastic = False
        self.drain_deadline = 0.0
        self.restart_times: List[float] = []
        self._stop_remote = False
        self._remote_error: Optional[str] = None
        self.reports_seen = 0
        self.remote_health: Dict = {}
        self.remote_services: Dict = {}

    def _thread_targets(self):
        return []                          # the Supervisor is the actor

    # -- report sink (called from a server connection thread) -----------------
    @property
    def stop_requested(self) -> bool:
        return self._stop_remote or self._stop.is_set()

    def stop_for(self, incarnation: int) -> bool:
        """Per-incarnation stop verdict for the report reply: superseded
        incarnations and exhausted slots are told to exit."""
        with self.lock:
            return (self.stop_requested or self.error is not None
                    or incarnation != self.incarnation)

    def apply_report(self, report: Dict, incarnation: int = 0) -> None:
        with self.lock:
            if incarnation != self.incarnation:
                return                     # stale incarnation — drop
            self.endpoint.note_report()
            if (self.phase == "waiting" and incarnation > 0
                    and getattr(self.endpoint, "attached_incarnation",
                                incarnation) is None):
                # the incarnation we presumed dead resumed reporting — it
                # was a stall, not a death: re-adopt it in place (the
                # restart the stall charged stays on the budget) instead
                # of stranding a live worker while the attach window
                # burns the rest of the budget
                self.endpoint.attach(incarnation)
                self.phase = "up"
            self.remote_health = report.get("health", {})
            self.remote_services = report.get("services", {})
            self.metrics.apply_remote(report.get("merged", {}))
            self.reports_seen += 1
            if not self.remote_health.get("healthy", True):
                self._remote_error = (self.remote_health.get("error")
                                      or "remote service failed")

    # -- lifecycle ------------------------------------------------------------
    def on_stop(self) -> None:
        self._stop_remote = True

    def join(self, timeout: float = 5.0) -> None:
        self.endpoint.shutdown(timeout=timeout)
        super().join(timeout=1.0)

    # -- the orchestrator's rollout-aggregation surface ------------------------
    @property
    def process(self):
        """The current incarnation's process (spawn mode; None otherwise)."""
        return getattr(self.endpoint, "process", None)

    @property
    def env_steps(self) -> int:
        return int(self.metrics.counter("env_steps"))

    @property
    def episodes_done(self) -> int:
        return int(self.metrics.counter("episodes"))

    @property
    def successes(self) -> int:
        return int(self.metrics.counter("successes"))

    @property
    def returns(self) -> List[float]:
        s = self.metrics.snapshot()["series"].get("return")
        if not s or not s["count"]:
            return []
        # the child ships a count/mean summary; expanding it preserves the
        # count-weighted global mean the orchestrator computes
        return [s["mean"]] * int(s["count"])


# ---------------------------------------------------------------------------
# the supervisor: one state machine for every non-local worker
# ---------------------------------------------------------------------------

class Supervisor(Service):
    """Owns N supervised worker slots under one :class:`RestartPolicy`.

    The single supervision thread launches each slot's endpoint, watches
    its liveness (process for spawn, heartbeat stream for connect), and on
    failure either relaunches within the restart budget (new incarnation,
    metrics folded monotonically) or marks the slot FAILED so schedulers
    fail fast — the one state machine PR 3's per-host monitor threads are
    replaced by."""

    def __init__(self, server, policy: RestartPolicy, *,
                 name: str = "supervisor", poll_s: float = 0.02):
        super().__init__(name, role="supervision")
        self.server = server
        self.policy = policy
        self.poll_s = poll_s
        self.slots: List[SupervisedWorker] = []
        # elastic autoscaling (enable_elastic arms it)
        self.elastic: Optional[ElasticPolicy] = None
        self._spec_factory = None
        self._signal_fn = None
        self._elastic_mode = "spawn"
        self._register = None
        self._elastic_seq = 0
        self._last_scale_t = 0.0
        server.set_hello_handler(self.handle_hello)

    # -- slot construction ----------------------------------------------------
    def add_spawned(self, spec: RemoteWorkerSpec) -> SupervisedWorker:
        """A slot whose incarnations are child processes of this host."""
        slot = SupervisedWorker(spec, SpawnedEndpoint(), self.server)
        self.slots.append(slot)
        return slot

    def add_connected(self, spec: RemoteWorkerSpec, *,
                      liveness_timeout_s: float = 0.0,
                      liveness_heartbeats: float = 10.0,
                      liveness_floor_s: float = 2.0) -> SupervisedWorker:
        """A slot filled by a worker dialing in (``repro.launch.worker``).
        ``liveness_timeout_s`` 0 = auto: ``liveness_heartbeats`` missed
        heartbeats, floored at ``liveness_floor_s`` (both flow from
        :class:`~repro.configs.base.SupervisionConfig`, so deployments on
        jittery networks can widen the stall window without slowing the
        heartbeat itself)."""
        timeout = liveness_timeout_s or max(
            liveness_heartbeats * spec.heartbeat_s, liveness_floor_s)
        endpoint = ConnectedEndpoint(
            liveness_timeout_s=timeout,
            attach_timeout_s=spec.connect_timeout_s)
        slot = SupervisedWorker(spec, endpoint, self.server)
        self.slots.append(slot)
        return slot

    # -- elastic autoscaling ---------------------------------------------------
    def enable_elastic(self, policy: ElasticPolicy, spec_factory,
                       signal_fn, *, mode: str = "spawn",
                       register=None) -> None:
        """Arm the autoscaler. ``spec_factory(seq)`` builds the spec for a
        new elastic worker; ``signal_fn()`` returns the current signal
        dict (``depth_frac``, ``staleness`` — see
        :class:`ElasticPolicy`); ``register(slot)`` lets the caller put a
        freshly added slot on its service registry. ``mode`` picks the
        endpoint lifecycle for scale-ups (``spawn`` or ``connect``)."""
        if mode not in ("spawn", "connect"):
            raise ValueError(f"elastic mode {mode!r} not in "
                             f"('spawn', 'connect')")
        self.elastic = policy
        self._spec_factory = spec_factory
        self._signal_fn = signal_fn
        self._elastic_mode = mode
        self._register = register

    # -- the worker.hello responder (runs on a server connection thread) ------
    def handle_hello(self, header: Dict) -> Dict:
        """Assign the dialing worker a free connect slot (optionally the
        specific one it asked for) and ship its spec. The server has
        already verified the shared token."""
        want = header.get("worker")
        for slot in self.slots:
            if slot.endpoint.mode != "connect":
                continue
            if want and slot.name != want:
                continue
            assigned = self._try_attach(slot)
            if assigned is not None:
                return assigned
        detail = f" {want!r}" if want else ""
        return {"err": f"no open worker slot{detail} — every slot is "
                       f"live, failed, or stopping (redial after the "
                       f"liveness window if its worker just died)"}

    def _try_attach(self, slot: SupervisedWorker) -> Optional[Dict]:
        with slot.lock:
            endpoint = slot.endpoint
            if (slot.error is not None or slot.stop_requested
                    or slot.phase not in ("new", "waiting")):
                return None
            if endpoint.failure() is not None:
                # the attach window lapsed but the supervision thread has
                # not processed it yet — let it account for the failure
                # first so the budget stays exact
                return None
            slot.incarnation += 1
            if slot.incarnation > 1:
                slot.metrics.begin_remote_incarnation()
            slot._remote_error = None
            endpoint.attach(slot.incarnation)
            slot.phase = "up"
            spec = dataclasses.replace(slot.spec,
                                       incarnation=slot.incarnation)
            self.metrics.inc("attaches")
            return {"ok": True, "name": slot.name,
                    "incarnation": slot.incarnation,
                    "spec": spec_to_wire(spec)}

    # -- supervision state machine --------------------------------------------
    def _run(self) -> None:
        for slot in self.slots:
            with slot.lock:
                self._launch(slot)
        while not self._stop.is_set():
            now = time.monotonic()
            # list(): _elastic_step appends from this same thread
            for slot in list(self.slots):
                if slot.phase == "draining":
                    self._drain_step(slot, now)
                else:
                    self._step(slot, now)
            if self.elastic is not None:
                self._elastic_step(now)
            time.sleep(self.poll_s)

    def _launch(self, slot: SupervisedWorker) -> None:
        """Begin the next incarnation (caller holds ``slot.lock``)."""
        if slot.endpoint.mode == "spawn":
            slot.incarnation += 1
            if slot.incarnation > 1:
                slot.metrics.begin_remote_incarnation()
            slot._remote_error = None
            slot.endpoint.launch(dataclasses.replace(
                slot.spec, incarnation=slot.incarnation))
            slot.phase = "up"
        elif (slot.endpoint.attached_incarnation is None
              or slot.endpoint.failure() is not None):
            # connect mode: (re)open the slot; handle_hello does the
            # attach (launch drops any dead attachment)
            slot.endpoint.launch(slot.spec)
            slot.phase = "waiting"
        else:
            slot.phase = "up"      # a worker dialed in before this loop
                                   # first ran — keep the live attachment

    def _step(self, slot: SupervisedWorker, now: float) -> None:
        with slot.lock:
            if slot.error is not None or slot.phase == "done":
                return
            if slot.stop_requested:
                slot.phase = "done"
                return
            if slot.phase == "backoff":
                if (slot.endpoint.mode == "connect"
                        and slot.endpoint.attached_incarnation
                        == slot.incarnation
                        and slot.endpoint.failure() is None):
                    slot.phase = "up"      # the stalled worker's reports
                    return                 # resumed before the relaunch
                if now >= slot.relaunch_at:
                    self._launch(slot)
                return
            if slot._remote_error is not None:
                reason = (f"reported a failed service: "
                          f"{slot._remote_error}")
            else:
                reason = slot.endpoint.failure()
            if reason is None:
                return
            self._on_failure(slot, reason, now)

    def _on_failure(self, slot: SupervisedWorker, reason: str,
                    now: float) -> None:
        """Policy decision for a dead incarnation (caller holds the lock)."""
        self.metrics.inc("failures")
        slot._remote_error = None
        slot.endpoint.shutdown(timeout=0.2)   # reap a dead child quickly
        if self.policy.mode != "on_failure":
            self._fail(slot, reason)
            return
        slot.restart_times = [t for t in slot.restart_times
                              if now - t <= self.policy.window_s]
        if len(slot.restart_times) >= self.policy.max_restarts:
            self._fail(slot, f"restart budget exhausted "
                             f"({len(slot.restart_times)} restart(s) in "
                             f"{self.policy.window_s:.0f}s); last failure: "
                             f"{reason}")
            return
        slot.restart_times.append(now)
        slot.restarts += 1
        slot.metrics.inc("restarts")
        self.metrics.inc("restarts")
        delay = self.policy.backoff_s(len(slot.restart_times))
        slot.relaunch_at = now + delay
        slot.phase = "backoff"

    def _fail(self, slot: SupervisedWorker, reason: str) -> None:
        slot.phase = "done"
        slot.mark_failed(RuntimeError(
            f"remote worker {slot.name!r} {reason}"))

    # -- elastic steps (supervision thread only) ------------------------------
    def _elastic_step(self, now: float) -> None:
        pol = self.elastic
        if now - self._last_scale_t < pol.interval_s:
            return
        try:
            signals = dict(self._signal_fn() or {})
        except Exception:              # noqa: BLE001 — a flaky signal
            return                     # source must not kill supervision
        active = [s for s in self.slots
                  if s.error is None and s.phase != "done"]
        draining = any(s.phase == "draining" for s in active)
        n = len(active)
        depth = float(signals.get("depth_frac", 0.5))
        staleness = float(signals.get("staleness", 0.0))
        stale = pol.staleness_cap > 0 and staleness > pol.staleness_cap
        infer_depth = float(signals.get("infer_queue_depth", 0.0))
        infer_fill = float(signals.get("infer_window_fill", 0.0))
        # inference-tier pressure: a hot tier means demand is outrunning
        # serving capacity — an extra scale-up trigger that also pins the
        # fleet (no scale-down) while the pressure lasts
        saturated = ((pol.tier_queue_hot > 0
                      and infer_depth >= pol.tier_queue_hot)
                     or (pol.tier_fill_hot > 0
                         and infer_fill >= pol.tier_fill_hot))
        self.metrics.set_gauge("elastic_workers", float(n))
        self.metrics.set_gauge("elastic_depth_frac", depth)
        self.metrics.set_gauge("elastic_staleness", staleness)
        self.metrics.set_gauge("elastic_infer_queue_depth", infer_depth)
        self.metrics.set_gauge("elastic_infer_window_fill", infer_fill)
        self.metrics.set_gauge("elastic_tier_saturated", float(saturated))
        if draining:
            return                     # one transition at a time
        if (n < pol.max_workers and not stale
                and (depth <= pol.scale_up_depth or saturated)):
            self._scale_up()
            self._last_scale_t = now
        elif (n > pol.min_workers and not saturated
              and (depth >= pol.scale_down_depth or stale)):
            self._scale_down(now)
            self._last_scale_t = now

    def _elastic_add(self, spec: RemoteWorkerSpec) -> SupervisedWorker:
        """Build the slot for a scale-up (seam: tests override this to
        inject fake endpoints)."""
        if self._elastic_mode == "connect":
            return self.add_connected(spec)
        return self.add_spawned(spec)

    def _scale_up(self) -> None:
        self._elastic_seq += 1
        spec = self._spec_factory(self._elastic_seq)
        slot = self._elastic_add(spec)
        slot.elastic = True
        if self._register is not None:
            try:
                self._register(slot)
            except Exception:          # noqa: BLE001 — registry hiccup
                pass                   # must not kill supervision
        with slot.lock:
            self._launch(slot)
        self.metrics.inc("scale_ups")

    def _scale_down(self, now: float) -> None:
        """Begin draining the NEWEST live elastic slot (LIFO keeps the
        stable core fleet untouched). The worker is told to stop via its
        next report reply; it flushes its in-flight segments in close()
        and exits — _drain_step retires the slot when the exit lands."""
        for slot in reversed(self.slots):
            if not slot.elastic or slot.error is not None:
                continue
            if slot.phase not in ("up", "waiting"):
                continue
            with slot.lock:
                slot.phase = "draining"
                slot._stop_remote = True
                slot.drain_deadline = now + self.elastic.drain_timeout_s
            self.metrics.inc("scale_downs")
            return

    def _drain_step(self, slot: SupervisedWorker, now: float) -> None:
        """Retire a draining slot once its worker exited (or the drain
        deadline passed). Deliberately NOT a failure: no budget charge,
        no error — schedulers keep running."""
        with slot.lock:
            if slot.phase != "draining":
                return
            endpoint = slot.endpoint
            exited = (endpoint.failure() is not None
                      or (endpoint.mode == "connect"
                          and endpoint.attached_incarnation is None))
            if not exited and now < slot.drain_deadline:
                return
            endpoint.shutdown(timeout=1.0)
            slot.phase = "done"
        self.metrics.inc("drains_completed")

    def on_stop(self) -> None:
        # raise every slot's cooperative stop flag even if the registry
        # stops the supervisor first — no slot may be relaunched past here
        for slot in self.slots:
            slot._stop_remote = True
