"""Disaggregated inference plane: shared continuous batching over the wire.

The paper's third isolation axis — inference physically decoupled from
rollouts — becomes a transport concern here. Instead of every remote
worker process hosting its own colocated
:class:`~repro.runtime.inference.InferenceService` (whose eq.-1 dynamic
window only ever sees ONE worker's requests), many rollout workers submit
action requests to one shared pool that continuously batches across all
of them:

  ``RolloutWorker`` ─ submit() ─▶ :class:`RemoteInferenceClient`
        │  (unchanged: same ``submit(...) -> Future`` contract)
        ▼  ``infer.submit`` / ``infer.result`` frames
  :class:`~repro.runtime.transport.server.TransportServer`
        ▼
  :class:`InferenceBroker` ─▶ shared ``InferenceService`` pool
        ▲                          │ weights / drain flag
        └── results (seq-tagged)   ▼
                         ``WeightStoreTransport`` ─▶ parent weight store

Wire protocol (PutStream-shaped: seq-numbered frames, cumulative acks,
reconnect replay):

  ``infer.open``    {client} → {ok, epoch, known_seq} — handshake; the
                    broker's ``epoch`` identifies its incarnation and
                    ``known_seq`` its dedup watermark for this client, so
                    a reconnecting client replays exactly the requests
                    the (possibly restarted) broker has never seen.
  ``infer.submit``  {client, seq} + encoded request body → {ok[, dup]} —
                    enqueue-only; a frame at-or-below the watermark is
                    re-ACKed, never re-executed (at-most-once per epoch).
  ``infer.result``  {client, ack, timeout} → {ok, base, epoch} + encoded
                    result list — long-poll delivery; ``ack`` is the
                    client's cumulative delivery index, results stay in
                    the outbox until acked so a lost reply is redelivered.

Exactly-once result delivery is the composition: the broker dedups
submits by seq within an epoch, redelivers un-acked results, and the
client resolves each pending future at most once (first delivery wins) —
so a mid-episode tier kill costs only re-execution, never a double or
dropped resolve.

Deployment shapes (``TransportConfig.inference_plane``):

  * ``"host"``  — the broker wraps the parent's own pool on the parent's
    ``TransportServer``; workers share the trainer host's accelerator.
  * ``"spawn"`` — :class:`InferencePlaneService` runs in a supervised
    child process with its OWN ``TransportServer`` (fixed port, so a
    restarted incarnation rebinds the same address and workers redial)
    and pulls weights from the parent through ``WeightStoreTransport``
    — the drain protocol rides the existing ``store.state`` poll.
"""
from __future__ import annotations

import collections
import os
import threading
import time
import uuid
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.runtime.service import Service

# Import-gated tracing (see transport.faults for the idiom): trace ids
# ride infer.submit headers so a broker-side span joins the caller's
# trace across the process boundary.
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None
from repro.runtime.transport.channel import (POLL_S, ChannelClosed,
                                             TransportError, WireClient,
                                             shared_memory)
from repro.runtime.transport.codec import decode_pytree, encode_pytree
from repro.runtime.transport.ring import ShmRing

__all__ = ["InferenceBroker", "RemoteInferenceClient",
           "InferencePlaneService"]


class _ClientState:
    """Per-client stream state: submit dedup watermark + result outbox.

    Outlives any single connection (that is the point — a redialing
    client finds its watermark and un-acked results still here)."""

    __slots__ = ("last_seq", "next_idx", "outbox", "cv")

    def __init__(self):
        self.last_seq = -1                 # submit dedup watermark
        self.next_idx = 0                  # next result delivery index
        # (delivery_idx, result dict) — pruned by cumulative acks
        self.outbox: "collections.deque[Tuple[int, Dict]]" = \
            collections.deque()
        self.cv = threading.Condition()


class InferenceBroker:
    """Server-side bridge from ``infer.*`` frames to a shared pool.

    Wraps anything with the ``submit(obs_tokens, frame, step) -> Future``
    contract (the local :class:`InferenceService` in host mode, the plane
    child's own pool in spawn mode). Stateless about connections: all
    stream state is per-client and keyed by the client id, so the same
    client may redial any number of times.
    """

    def __init__(self, service: Any):
        self._service = service
        # epoch identifies THIS broker incarnation: a client that sees a
        # new epoch knows every in-flight request and ack is void
        self.epoch = uuid.uuid4().hex[:16]
        self._clients: Dict[str, _ClientState] = {}
        self._lock = threading.Lock()
        self._stats: Dict[str, float] = collections.defaultdict(float)

    def _client(self, name: str) -> _ClientState:
        with self._lock:
            st = self._clients.get(name)
            if st is None:
                st = self._clients[name] = _ClientState()
            return st

    # -- stats -----------------------------------------------------------------
    def _inc(self, key: str, by: float = 1.0) -> None:
        with self._lock:
            self._stats[key] += by

    def stats(self) -> Dict[str, float]:
        with self._lock:
            out = dict(self._stats)
        out["clients"] = float(len(self._clients))
        out["outbox_depth"] = float(sum(
            len(st.outbox) for st in list(self._clients.values())))
        return out

    # -- endpoint handlers -----------------------------------------------------
    def handle_open(self, h: Dict) -> Dict:
        st = self._client(str(h["client"]))
        self._inc("opens")
        return {"ok": True, "epoch": self.epoch, "known_seq": st.last_seq}

    def handle_submit(self, h: Dict, body: bytes) -> Dict:
        st = self._client(str(h["client"]))
        seq = int(h["seq"])
        with st.cv:
            if seq <= st.last_seq:         # replayed frame: already queued
                self._inc("dup_submits")
                return {"ok": True, "dup": True}
            st.last_seq = seq
        if _tel is not None and h.get("tr") is not None:
            # joins the submitting client's trace across the wire
            _tel.instant("broker.submit", cat="inference",
                         trace=int(h["tr"]),
                         args={"client": str(h["client"]), "seq": seq},
                         flow="step")
        req = decode_pytree(body, copy=True)
        fut = self._service.submit(np.asarray(req["obs"]),
                                   None if req["frame"] is None
                                   else np.asarray(req["frame"]),
                                   int(req["step"]))
        fut.add_done_callback(
            lambda f, st=st, seq=seq: self._deliver(st, seq, f))
        self._inc("submits")
        return {"ok": True}

    def _deliver(self, st: _ClientState, seq: int, fut: Future) -> None:
        err = fut.exception()
        if err is not None:
            res: Dict = {"seq": seq, "error": f"{type(err).__name__}: {err}"}
        else:
            res = dict(fut.result())
            res["seq"] = seq
        with st.cv:
            st.outbox.append((st.next_idx, res))
            st.next_idx += 1
            st.cv.notify_all()

    def handle_result(self, h: Dict) -> Tuple[Dict, bytes]:
        st = self._client(str(h["client"]))
        ack = int(h.get("ack", 0))
        timeout = float(h.get("timeout", 0.0))
        deadline = time.monotonic() + timeout
        with st.cv:
            # cumulative ack prunes delivered results; an ack beyond what
            # this broker ever delivered is a stale-epoch client's — the
            # client resets to 0 once it sees our epoch, so just ignore it
            if ack <= st.next_idx:
                while st.outbox and st.outbox[0][0] < ack:
                    st.outbox.popleft()
                    self._inc("results_acked")
            while not st.outbox:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                st.cv.wait(remaining)
            if not st.outbox:
                return {"ok": False, "epoch": self.epoch}, b""
            base = st.outbox[0][0]
            items = [r for _, r in st.outbox]
        self._inc("results_sent", float(len(items)))
        return ({"ok": True, "base": base, "count": len(items),
                 "epoch": self.epoch}, encode_pytree(items))


class RemoteInferenceClient:
    """Client half of the inference plane: ``submit(...) -> Future`` over
    the wire, drop-in for :class:`InferenceService` in rollout workers.

    Two connections: submits ride a request/response wire (large bodies
    out-of-band via per-message SHM, like ``ShmChannel``), results arrive
    on a dedicated long-poll wire so a parked result poll never blocks a
    submit. With ``use_ring=True`` result payloads travel through a
    persistent server→client SHM ring (the ``want_ring`` data plane) —
    worthwhile for same-host workers with large action payloads.

    Replay discipline (both redial paths end at the same invariant —
    every pending seq the broker has not seen gets re-submitted):

      * submit-wire reconnect → the ``on_reconnect`` hook re-runs the
        ``infer.open`` handshake and replays pending > ``known_seq``;
      * ANY poll reply — including an empty ``ok: False`` one — carrying
        a new epoch (tier restarted and the poll wire redialed first) →
        reset the ack to 0 and re-submit every pending request through
        the submit wire (the broker's per-epoch seq dedup makes
        overlapping replays harmless). Empty polls matter: when every
        pending request was in flight at the kill, no result will ever
        arrive for the old epoch and the empty poll is the only signal.

    Futures resolve exactly once: results are popped from the pending map
    under the lock, so a redelivered result finds no future and is
    dropped.
    """

    def __init__(self, address: Tuple[str, int], *, client_id: str,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 use_ring: bool = False,
                 ring_bytes: int = 2 << 20):
        self._id = client_id
        self._lock = threading.Lock()
        self._pending: Dict[int, Tuple[bytes, Future]] = {}
        self._next_seq = 0
        self._ack = 0
        self._epoch: Optional[str] = None
        self._closed = threading.Event()
        self.replays = 0
        self.epoch_changes = 0
        self.results = 0
        self._ring: Optional[ShmRing] = None
        self._ring_bytes = int(ring_bytes)
        self._use_ring = bool(use_ring and shared_memory is not None)
        wire_kw = dict(connect_timeout=connect_timeout,
                       shm_threshold=shm_threshold,
                       reconnect_attempts=reconnect_attempts,
                       reconnect_backoff_s=reconnect_backoff_s)
        self._wire = WireClient(address, on_reconnect=self._resync,
                                **wire_kw)
        self._poll = WireClient(address, on_reconnect=self._poll_reconnect,
                                **wire_kw)
        rh, _ = self._wire.request({"m": "infer.open", "client": self._id})
        self._epoch = rh["epoch"]
        self._next_seq = int(rh.get("known_seq", -1)) + 1
        if self._use_ring:
            self._open_result_ring(self._poll.request)
        self._thread = threading.Thread(target=self._poll_loop, daemon=True,
                                        name=f"infer-client-{client_id}")
        self._thread.start()

    # -- submit path -----------------------------------------------------------
    def submit(self, obs_tokens: np.ndarray, frame: Optional[np.ndarray],
               step: int) -> Future:
        """Asynchronous request; the rollout worker suspends on the future.
        Same contract as ``InferenceService.submit``."""
        body = encode_pytree({
            "obs": np.asarray(obs_tokens),
            "frame": None if frame is None else np.asarray(frame),
            "step": int(step),
        })
        fut: Future = Future()
        with self._lock:
            seq = self._next_seq
            self._next_seq += 1
            self._pending[seq] = (body, fut)
        # the wire lock is NOT held while registering pending (the
        # reconnect hook runs under it and takes self._lock — registering
        # first, sending after keeps the order consistent)
        header = {"m": "infer.submit", "client": self._id, "seq": seq}
        if _tel is not None:
            header.update(_tel.wire_ctx())
        try:
            self._wire.request(header, body, oob=True)
        except (TransportError, ChannelClosed) as e:
            with self._lock:
                self._pending.pop(seq, None)
            if not fut.done():
                fut.set_exception(e)
        return fut

    def _resync(self) -> None:
        """Submit-wire reconnect hook (runs under the wire's call lock →
        raw_request only): re-handshake, then replay every pending seq
        the broker's watermark says it never received."""
        rh, _ = self._wire.raw_request({"m": "infer.open",
                                        "client": self._id})
        known = int(rh.get("known_seq", -1))
        with self._lock:
            if rh["epoch"] != self._epoch:
                self._epoch = rh["epoch"]
                self._ack = 0
                self.epoch_changes += 1
            replay = sorted((s, b) for s, (b, _f) in self._pending.items()
                            if s > known)
        for seq, body in replay:
            self._wire.raw_request({"m": "infer.submit", "client": self._id,
                                    "seq": seq}, body)
            self.replays += 1

    # -- result path -----------------------------------------------------------
    def _open_result_ring(self, request) -> None:
        ring = ShmRing.create(self._ring_bytes)
        try:
            request({"m": "ring.open", "s2c": ring.name})
        except BaseException:
            ring.close()
            ring.unlink()
            raise
        old, self._ring = self._ring, ring
        if old is not None:
            old.close()
            old.unlink()

    def _poll_reconnect(self) -> None:
        # fresh connection → the server side lost its ring attachment;
        # hand it a fresh one (raw_request: we are under the call lock)
        if self._use_ring:
            self._open_result_ring(self._poll.raw_request)

    def _result_header(self, slice_timeout: float) -> Dict:
        h = {"m": "infer.result", "client": self._id, "ack": self._ack,
             "timeout": slice_timeout}
        if self._ring is not None:
            h["want_ring"] = True
        return h

    def _poll_loop(self) -> None:
        # NOT the shared long_poll idiom: that helper discards ok:False
        # replies, and an EMPTY poll against a restarted tier is the only
        # epoch-change signal when every pending request was in flight at
        # the kill (the old results died with the old broker, and rollout
        # workers parked on those futures submit nothing new — so nothing
        # else would ever trigger the replay).
        while not self._closed.is_set():
            try:
                resp, body = self._poll.request(self._result_header(POLL_S))
            except (TransportError, ChannelClosed):
                if self._poll.closed and not self._closed.is_set():
                    # redial budget exhausted — fail fast so rollout
                    # workers are not parked on futures that cannot resolve
                    self._fail_pending(ChannelClosed(
                        "inference plane unreachable"))
                    return
                time.sleep(0.05)
                continue
            self._check_epoch(str(resp["epoch"]))
            if not resp.get("ok"):
                continue
            if resp.get("ring_nbytes") is not None:
                body = self._ring.pop(timeout=5.0)
                if body is None or len(body) != resp["ring_nbytes"]:
                    continue               # torn ring record: redelivered
            self._consume(resp, decode_pytree(body, copy=True))

    def _check_epoch(self, epoch: str) -> None:
        """A reply carrying an unfamiliar epoch means the tier restarted:
        void the ack (delivery indices reset with the broker) and
        re-submit everything still pending (per-epoch seq dedup on the
        broker makes overlapping replays harmless)."""
        with self._lock:
            if epoch == self._epoch:
                return
            self._epoch = epoch
            self._ack = 0
            self.epoch_changes += 1
            replay = sorted((s, b) for s, (b, _f) in self._pending.items())
        for seq, body in replay:
            try:
                self._wire.request({"m": "infer.submit",
                                    "client": self._id, "seq": seq}, body,
                                   oob=True)
                self.replays += 1
            except (TransportError, ChannelClosed):
                return                      # the submit wire's own hook
                                            # will retry on its next redial

    def _consume(self, resp: Dict, items: List[Dict]) -> None:
        with self._lock:
            futs = []
            for i, item in enumerate(items):
                item = dict(item)
                seq = int(item.pop("seq"))
                got = self._pending.pop(seq, None)
                if got is not None:
                    futs.append((got[1], item))
                self._ack = max(self._ack, int(resp["base"]) + i + 1)
        for fut, item in futs:              # resolve outside the lock
            if fut.done():
                continue
            if "error" in item:
                fut.set_exception(TransportError(item["error"]))
            else:
                fut.set_result(item)
                self.results += 1

    def _fail_pending(self, err: Exception) -> None:
        with self._lock:
            pending, self._pending = list(self._pending.values()), {}
        for _body, fut in pending:
            if not fut.done():
                fut.set_exception(err)

    # -- introspection ---------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        with self._lock:
            pending = len(self._pending)
        return {"pending": float(pending), "replays": float(self.replays),
                "epoch_changes": float(self.epoch_changes),
                "results": float(self.results),
                "reconnects": float(self._wire.reconnects
                                    + self._poll.reconnects)}

    def close(self) -> None:
        self._closed.set()
        self._wire.close()
        self._poll.close()                 # unblocks the parked long-poll
        self._thread.join(timeout=5.0)
        self._fail_pending(ChannelClosed("inference client closed"))
        if self._ring is not None:
            self._ring.close()
            self._ring.unlink()
            self._ring = None


class InferencePlaneService(Service):
    """The spawn-mode inference tier: a shared pool + broker behind its
    own ``TransportServer``, pulling weights from the parent store.

    Binds its listener at CONSTRUCTION (like ``TransportServer``), so a
    supervised restart of the same spec rebinds the same fixed port and
    workers redial transparently. The service thread bridges the pool's
    autoscaling gauges (queue depth, window fill) and the broker's stream
    counters into this service's registry — in spawn mode that registry
    is what ``worker.report`` ships to the parent, which is how
    ``ElasticPolicy`` sees the shared tier's pressure.
    """

    def __init__(self, cfg, rt, parent_address: Tuple[str, int], *,
                 listen: Tuple[str, int] = ("127.0.0.1", 0),
                 temperature: float = 1.0, seed: int = 0,
                 use_shm: bool = False, shm_threshold: int = 1 << 16,
                 connect_timeout: float = 20.0,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 token: str = ""):
        super().__init__("inference-plane", role="inference")
        from repro.runtime.inference import InferenceService
        from repro.runtime.transport.server import TransportServer
        from repro.runtime.transport.weights import WeightStoreTransport
        self.store = WeightStoreTransport(
            parent_address, use_shm=use_shm, shm_threshold=shm_threshold,
            connect_timeout=connect_timeout,
            reconnect_attempts=reconnect_attempts,
            reconnect_backoff_s=reconnect_backoff_s)
        self.pool = InferenceService(cfg, self.store, rt,
                                     temperature=temperature, seed=seed)
        self.server = TransportServer(host=listen[0], port=listen[1],
                                      shm_threshold=shm_threshold,
                                      name="infer-wire", token=token)
        self.broker = InferenceBroker(self.pool)
        self.server.set_inference(self.broker)
        self.address: Tuple[str, int] = self.server.address

    # -- service surface -------------------------------------------------------
    def on_start(self) -> None:
        self.pool.start()
        self.server.start()

    def _run(self) -> None:
        while not self._stop.wait(0.2):
            snap = self.pool.metrics.snapshot()
            for key in ("queue_depth", "window_fill", "weight_version"):
                if key in snap["gauges"]:
                    self.metrics.set_gauge(key, snap["gauges"][key])
            for key, val in self.broker.stats().items():
                self.metrics.set_gauge(f"broker_{key}", val)

    def on_stop(self) -> None:
        self.server.stop()
        self.pool.stop()
        self.server.join(timeout=5.0)
        self.pool.join(timeout=5.0)
        self.store.close()

    def utilization(self) -> float:
        return self.pool.utilization()
