"""TransportServer: the parent-process endpoint of the transport layer.

One listening socket serves every remote worker of a system. It is itself
a :class:`~repro.runtime.service.Service` (role ``transport``) registered
FIRST on the bus, so it starts before any remote host spawns a child and
stops after every child has been told to exit.

Exposed endpoints (JSON header ``m`` field):

  ======================  ==================================================
  ``chan.put``            push one encoded item into a hosted channel —
                          the channel's own backpressure policy answers
  ``chan.put_many``       one codec blob carrying a whole flush (an
                          episode's segments); per-item verdict vector back
  ``chan.put_stream``     one pipelined put-stream frame: applied at most
                          once per ``(chan, stream, seq)`` — replayed
                          frames are re-ACKed from the stored verdicts,
                          never re-applied (exactly-once across reconnects)
  ``chan.pop``            blocking ``pop_batch(n, timeout)`` (bounded
                          slices; clients long-poll)
  ``chan.pop_many``       coalesced drain: up to ``n`` items, ONE blob —
                          blocks only for the first item
  ``chan.len/stats``      depth / stats snapshot
  ``stream.open``         put-stream handshake: registers the dedup state
                          and (ring mode) attaches the client→server ring
  ``ring.open``           attaches this connection's server→client ring
                          for ``want_ring`` pop replies
  ``store.acquire``       newest weights with version > ``newer_than``
                          (encoded once per version, then cache-served)
  ``store.state``         (version, draining) — the drain protocol's poll
  ``store.drain``         remote ``begin_publish`` (drain signal)
  ``store.publish``       remote publish (a trainer across the wire)
  ``infer.open``          inference-plane handshake: broker epoch + the
                          client's submit-dedup watermark (replay base)
  ``infer.submit``        one seq-numbered action request for the shared
                          inference pool (at-most-once per epoch)
  ``infer.result``        long-poll result delivery with cumulative acks
                          (un-acked results are redelivered)
  ``worker.hello``        connect-mode handshake: shared-token auth, then
                          the supervisor assigns a slot and ships its spec
  ``worker.report``       child → parent metrics/health bridge; the reply
                          carries the per-incarnation stop flag
  ``ping``                liveness probe
  ======================  ==================================================

Every connection gets its own handler thread; blocking pops therefore
never head-of-line-block other clients. Large response bodies go
out-of-band via shared memory when the client asks (``want_shm``) — the
server defers the unlink until the same connection's next frame, which is
the client's implicit ack — or through the connection's persistent ring
(``want_ring``), which needs no per-message ack at all.

Orphan sweep: a client that dies between creating a request SHM segment
and unlinking it (creator-unlinks-after-ack) leaks the segment — its own
resource tracker is shared with the parent and therefore outlives it. The
server remembers every client-created segment name it has seen and
unlinks any still present when it closes. Ring segments need no LRU:
their lifetime IS the connection's, so the handler sweeps its own rings
in ``finally`` (the creator's unlink having won is fine — both sides
tolerate the name being gone).

Segment-churn accounting: the registry counters
``shm_segments_created`` / ``shm_segments_attached`` /
``shm_segments_unlinked`` (per-message data plane) vs
``ring_records_in/out`` + ``rings_opened`` (persistent data plane) make
the ring-vs-segment trade observable in ``metrics()["services"]``, not
just in the benchmark.
"""
from __future__ import annotations

import collections
import contextlib
import os
import socket
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.runtime.service import Service
from repro.runtime.transport.channel import shared_memory, shm_read, shm_write
from repro.runtime.transport.codec import (decode_pytree, encode_pytree,
                                           recv_frame, send_frame)
from repro.runtime.transport.resilience import (TransportJournal, recover,
                                                sweep_stale_shm)
from repro.runtime.transport.ring import RingError, ShmRing

# fault injection is gated on the IMPORT, not just the call: with
# REPRO_FAULTS unset the faults module never loads and every fault site
# is one `is None` check (inertness is tested, not assumed)
if os.environ.get("REPRO_FAULTS"):
    from repro.runtime.transport.faults import fault_point as _fault
else:
    _fault = None

# import-gated tracing (runtime.telemetry): the server joins producer
# trace ids from frame headers into its own apply spans, folds child
# trace buffers shipped via worker.report, and serves trace.dump
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:
    _tel = None

__all__ = ["TransportServer"]


class _ConnContext:
    """Per-connection transport state: the attached ring endpoints."""

    __slots__ = ("c2s", "s2c")

    def __init__(self):
        self.c2s: Optional[ShmRing] = None    # put-stream payloads in
        self.s2c: Optional[ShmRing] = None    # pop replies out

    def rings(self) -> List[ShmRing]:
        return [r for r in (self.c2s, self.s2c) if r is not None]


class _StreamState:
    """Dedup state for one put stream, keyed by (channel, stream id).

    Survives the stream's connection (that is the point: a reconnect
    replays the window and the state says what was already applied).
    ``acks`` keeps the last few windows of verdicts so a replayed frame
    can be re-ACKed faithfully.
    """

    __slots__ = ("last_seq", "acks", "keep", "lock", "ack_every",
                 "pending_acks", "window")

    def __init__(self, window: int, ack_every: int = 1):
        self.window = window
        self.last_seq = -1
        self.acks: "collections.OrderedDict[int, List[bool]]" = \
            collections.OrderedDict()
        self.keep = max(4 * window, 64)
        # cumulative acking: reply once per `ack_every` frames (a reply
        # per frame costs the producer a receiver-thread wakeup per
        # flush); duplicates and stream.flush force an immediate drain
        self.ack_every = max(1, min(ack_every, max(window // 2, 1)))
        self.pending_acks: Dict[int, List[bool]] = {}
        # serializes dedup-check + apply: a frame replayed on a fresh
        # connection must not race its original, still stalled on the
        # dying one (e.g. a block-policy put)
        self.lock = threading.Lock()

    def record(self, seq: int, verdicts: List[bool]) -> None:
        self.last_seq = seq
        self.acks[seq] = verdicts
        self.pending_acks[seq] = verdicts
        while len(self.acks) > self.keep:
            self.acks.popitem(last=False)

    def drain_acks(self) -> Dict[str, List[bool]]:
        out = {str(k): v for k, v in self.pending_acks.items()}
        self.pending_acks = {}
        return out


class TransportServer(Service):
    """Serves channels + the weight store to remote worker processes."""

    #: how many client-created SHM segment names to remember for the
    #: orphan sweep (normal clients unlink promptly, so the live set is
    #: tiny; the bound only caps pathological churn)
    SHM_SWEEP_LIMIT = 4096

    def __init__(self, host: str = "127.0.0.1", port: int = 0, *,
                 shm_threshold: int = 1 << 16, name: str = "transport",
                 token: str = "", journal: Optional[TransportJournal] = None,
                 weight_lane_bytes: int = 0):
        super().__init__(name, role="transport")
        self._channels: Dict[str, Any] = {}
        self._store = None
        # resilience journal: stream watermarks are appended on the put
        # path; compaction runs on the accept loop's idle tick
        self._journal = journal
        self._sinks: Dict[str, Any] = {}          # worker name -> host
        self._token = token
        self._hello: Optional[Callable[[Dict], Dict]] = None
        self._infer: Optional[Any] = None
        # metrics.snapshot endpoint source: the orchestrator points this
        # at its TelemetrySink (whole-registry sample); unset, the
        # endpoint serves this server's own registry
        self.snapshot_provider: Optional[Callable[[], Dict]] = None
        self._shm_threshold = shm_threshold
        # put-stream dedup state, keyed by (chan, stream id); survives the
        # stream's connection so replays after a reconnect are applied at
        # most once (bounded LRU: streams are few and long-lived)
        self._streams: "collections.OrderedDict[Tuple[str, str], _StreamState]" = \
            collections.OrderedDict()
        self._streams_lock = threading.Lock()
        self._conns: list = []
        self._conn_lock = threading.Lock()
        # client-created SHM segments seen on requests, for the orphan
        # sweep at close (an OrderedDict doubles as a bounded LRU set)
        self._client_shm: "collections.OrderedDict[str, None]" = \
            collections.OrderedDict()
        self._client_shm_lock = threading.Lock()
        # weights are encoded once per published version, then cache-served
        # to every remote consumer (the LlamaRL-style broadcast amortized)
        self._weights_cache: Tuple[int, Optional[bytes]] = (-1, None)
        self._cache_lock = threading.Lock()
        # broadcast weight lane: one persistent ShmRing holding the newest
        # version's encoded blob; same-host readers attach by NAME and
        # copy by absolute POSITION from the acquire reply — no
        # per-acquire segment churn, no per-reader ring state
        self._lane_bytes = int(weight_lane_bytes)
        self._lane: Optional[ShmRing] = None
        self._lane_info: Tuple[int, Optional[Dict]] = (-1, None)
        self._lane_lock = threading.Lock()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))         # bound at construction so
        self._listener.listen(64)                 # specs can carry the port
        self._listener.settimeout(0.2)
        self.address: Tuple[str, int] = self._listener.getsockname()[:2]

    # -- endpoint registration ------------------------------------------------
    def add_channel(self, name: str, channel: Any) -> None:
        self._channels[name] = channel

    def set_store(self, store: Any) -> None:
        self._store = store

    def register_worker_sink(self, name: str, host: Any) -> None:
        """Route ``worker.report`` frames for ``name`` to ``host``."""
        self._sinks[name] = host

    def set_hello_handler(self, handler: Callable[[Dict], Dict]) -> None:
        """Install the ``worker.hello`` responder (the Supervisor): gets
        the authenticated request header, answers the slot assignment."""
        self._hello = handler

    def set_inference(self, broker: Any) -> None:
        """Install the ``infer.*`` responder (an
        :class:`~repro.runtime.transport.inference_plane.InferenceBroker`):
        the shared continuous-batching pool served behind this server."""
        self._infer = broker

    # -- service surface ------------------------------------------------------
    def _run(self) -> None:
        # a SIGKILLed previous incarnation cannot run its own finally
        # blocks — sweep its leaked rings/segments before serving (names
        # encode the creator pid; only dead-creator segments are touched)
        swept = sweep_stale_shm()
        if swept:
            self.metrics.inc("shm_stale_swept", float(swept))
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                if self._journal is not None:
                    # idle tick: bound how long group-commit records from
                    # purely local producers can sit in the buffer
                    self._journal.flush()
                    if self._journal.should_compact():
                        self._journal.compact(self._stream_records)
                        self.metrics.inc("journal_compactions")
                continue
            except OSError:            # listener closed during shutdown
                break
            conn.settimeout(None)
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conn_lock:
                self._conns.append(conn)
            self.metrics.inc("connections")
            threading.Thread(target=self._serve, args=(conn,), daemon=True,
                             name=f"{self.name}-conn").start()

    def on_stop(self) -> None:
        try:
            self._listener.close()
        except OSError:
            pass
        with self._conn_lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._sweep_orphan_shm()
        with self._lane_lock:
            lane, self._lane = self._lane, None
        if lane is not None:
            lane.close()
            lane.unlink()
        if self._journal is not None:
            # final snapshot so a later --resume-journal replays one
            # compact file instead of the whole log
            try:
                self._journal.compact(self._stream_records)
            except OSError:
                pass
            self._journal.close()

    def _note_client_shm(self, name: str) -> None:
        with self._client_shm_lock:
            self._client_shm[name] = None
            self._client_shm.move_to_end(name)
            while len(self._client_shm) > self.SHM_SWEEP_LIMIT:
                self._client_shm.popitem(last=False)

    def _sweep_orphan_shm(self) -> None:
        """Unlink client-created segments whose creator died before its
        post-ack unlink (e.g. a SIGKILLed producer). Normal segments are
        long gone — attach fails and the name is skipped."""
        if shared_memory is None:
            return
        with self._client_shm_lock:
            names, self._client_shm = list(self._client_shm), \
                collections.OrderedDict()
        for name in names:
            try:
                seg = shared_memory.SharedMemory(name=name)
            except (FileNotFoundError, OSError):
                continue
            seg.close()
            try:
                seg.unlink()
            except FileNotFoundError:
                pass
            self.metrics.inc("shm_orphans_swept")

    # -- connection loop ------------------------------------------------------
    def _serve(self, conn: socket.socket) -> None:
        pending_shm = None                 # reply segment awaiting its ack
        ctx = _ConnContext()
        # buffered reads: a pipelined producer's back-to-back frames are
        # consumed per-buffer, not per-syscall
        rfile = conn.makefile("rb")
        try:
            while not self._stop.is_set():
                frame = recv_frame(rfile)
                if pending_shm is not None:
                    # the next frame (or EOF) is the client's implicit ack
                    pending_shm.close()
                    try:
                        pending_shm.unlink()
                        self.metrics.inc("shm_segments_unlinked")
                    except FileNotFoundError:
                        pass
                    pending_shm = None
                if frame is None:
                    break
                if _fault is not None:
                    _fault("server.frame")
                header, body = frame
                if header.get("shm"):      # request body arrived via SHM
                    self._note_client_shm(header["shm"])
                    self.metrics.inc("shm_segments_attached")
                    body = shm_read(header["shm"], header["shm_size"])
                self.metrics.inc("requests")
                self.metrics.inc("rx_bytes", float(len(body)))
                resp, resp_body = self._dispatch(header, body, ctx)
                if resp is None:           # cumulative-ack frame: no reply
                    continue
                if resp_body:
                    # the ring (persistent, no per-message ack) wins over
                    # per-message segments when the connection has one
                    if (header.get("want_ring") and ctx.s2c is not None
                            and ctx.s2c.push(resp_body, timeout=2.0)):
                        self.metrics.inc("ring_records_out")
                        self.metrics.inc("ring_bytes_out",
                                         float(len(resp_body)))
                        resp = {**resp, "ring_nbytes": len(resp_body)}
                        resp_body = b""
                    elif (header.get("want_shm")
                            and shared_memory is not None
                            and len(resp_body) >= self._shm_threshold):
                        pending_shm = shm_write(resp_body)
                        self.metrics.inc("shm_segments_created")
                        resp = {**resp, "shm": pending_shm.name,
                                "shm_size": len(resp_body)}
                        resp_body = b""
                if self._journal is not None:
                    # group-commit boundary: every journaled record this
                    # reply (or stream-ack batch) depends on must be in
                    # the page cache before the peer can see the reply
                    self._journal.flush()
                self.metrics.inc(
                    "tx_bytes", float(send_frame(conn, resp, resp_body)))
        except (OSError, ValueError, RingError):
            pass                           # peer vanished — their problem
        finally:
            if pending_shm is not None:
                pending_shm.close()
                try:
                    pending_shm.unlink()
                    self.metrics.inc("shm_segments_unlinked")
                except FileNotFoundError:
                    pass
            # ring lifetime == connection lifetime: sweep this handler's
            # rings (the creator's own unlink having won is fine)
            for ring in ctx.rings():
                ring.close()
                ring.unlink()
                self.metrics.inc("rings_swept")
            for closer in (rfile.close, conn.close):
                try:
                    closer()
                except OSError:
                    pass
            with self._conn_lock:
                if conn in self._conns:
                    self._conns.remove(conn)

    # -- put-stream dedup state ----------------------------------------------
    #: put-stream dedup states kept (LRU). Evicting a LIVE stream's state
    #: forfeits its exactly-once guarantee on the next replay, so the
    #: bound sits far above any real topology (streams ≈ 2 per worker)
    #: and evictions are surfaced as a counter.
    STREAM_STATE_LIMIT = 4096

    def _stream_state(self, chan: str, stream: str, window: int = 32,
                      ack_every: int = 1) -> _StreamState:
        key = (chan, stream)
        with self._streams_lock:
            st = self._streams.get(key)
            if st is None:
                st = self._streams[key] = _StreamState(window, ack_every)
            self._streams.move_to_end(key)
            while len(self._streams) > self.STREAM_STATE_LIMIT:
                self._streams.popitem(last=False)
                self.metrics.inc("stream_states_evicted")
            return st

    # -- request dispatch -----------------------------------------------------
    def _dispatch(self, h: Dict, body: bytes,
                  ctx: Optional[_ConnContext] = None) -> Tuple[Dict, bytes]:
        ctx = ctx if ctx is not None else _ConnContext()
        try:
            m = h.get("m")
            if m == "chan.put":
                ok = self._channels[h["chan"]].put(decode_pytree(body))
                if _tel is not None and h.get("tr") is not None:
                    _tel.instant("server.apply", cat="transport",
                                 trace=int(h["tr"]),
                                 args={"chan": h["chan"]}, flow="step")
                return {"ok": bool(ok)}, b""
            if m == "chan.put_many":
                items = decode_pytree(body)
                chan = self._channels[h["chan"]]
                verdicts = [bool(v) for v in
                            self._apply_put(chan, items, body)]
                if _tel is not None and h.get("tr") is not None:
                    _tel.instant("server.apply", cat="transport",
                                 trace=int(h["tr"]),
                                 args={"chan": h["chan"],
                                       "count": len(items)}, flow="step")
                return {"ok": all(verdicts),
                        "verdicts": verdicts}, b""
            if m == "ring.open":
                # client-created rings for this connection; re-open on the
                # same connection (shouldn't happen) replaces cleanly
                if h.get("c2s"):
                    if ctx.c2s is not None:
                        ctx.c2s.close()
                    ctx.c2s = ShmRing.attach(h["c2s"])
                if h.get("s2c"):
                    if ctx.s2c is not None:
                        ctx.s2c.close()
                    ctx.s2c = ShmRing.attach(h["s2c"])
                self.metrics.inc("rings_opened")
                return {"ok": True}, b""
            if m == "stream.open":
                if h["chan"] not in self._channels:
                    return {"err": f"unknown channel {h['chan']!r}"}, b""
                st = self._stream_state(h["chan"], h["stream"],
                                        int(h.get("window", 32)),
                                        int(h.get("ack_every", 1)))
                if h.get("ring"):
                    if ctx.c2s is not None:
                        ctx.c2s.close()
                    ctx.c2s = ShmRing.attach(h["ring"])
                    self.metrics.inc("rings_opened")
                return {"ok": True, "last_seq": st.last_seq}, b""
            if m == "stream.flush":
                st = self._stream_state(h["chan"], h["stream"])
                with st.lock:
                    return {"ok": True, "acks": st.drain_acks()}, b""
            if m == "stream.tune":
                # adaptive streaming: the client retunes the server's ack
                # cadence online (bounded by the handshake window, like
                # stream.open); pending acks drain immediately so a
                # shrunken window frees itself without waiting out the
                # OLD cadence
                st = self._stream_state(h["chan"], h["stream"])
                with st.lock:
                    st.ack_every = max(1, min(int(h.get("ack_every", 1)),
                                              max(st.window // 2, 1)))
                    acks = st.drain_acks() if st.pending_acks else None
                self.metrics.inc("stream_tunes")
                if acks:
                    return {"ok": True, "acks": acks}, b""
                return None, b""
            if m == "chan.put_stream":
                # ring payloads are consumed UNCONDITIONALLY (records and
                # frames must stay aligned), dedup decides application
                if h.get("ring_nbytes") is not None:
                    if ctx.c2s is None:
                        return {"err": "put_stream ring frame without an "
                                       "attached ring"}, b""
                    body = ctx.c2s.pop(timeout=5.0)
                    if body is None or len(body) != h["ring_nbytes"]:
                        return {"err": "put ring record missing or "
                                       "truncated"}, b""
                    self.metrics.inc("ring_records_in")
                    self.metrics.inc("ring_bytes_in", float(len(body)))
                    # the ingest pop is a genuine copy (decoded items are
                    # stored long-lived in the hosted channel, so they
                    # must not view the reclaimable ring) — counted so
                    # the zero-copy claim is auditable end to end
                    self.metrics.inc("bytes_copied", float(len(body)))
                st = self._stream_state(h["chan"], h["stream"])
                seq = int(h["seq"])
                with st.lock:
                    if seq <= st.last_seq:   # replayed, already applied
                        self.metrics.inc("stream_dup_frames")
                        acks = st.drain_acks()
                        acks[str(seq)] = st.acks.get(seq, [])
                        return {"ok": True, "dup": True, "acks": acks}, b""
                    if _fault is not None:
                        _fault("server.stream_apply")
                    # join the producer's trace: the frame header carries
                    # its flush span's ids, so this apply slice lands on
                    # the same trace id in the exported timeline
                    apply_span = (
                        _tel.span("server.apply", cat="transport",
                                  trace=int(h["tr"]), parent=h.get("sp"),
                                  args={"chan": h["chan"], "seq": seq,
                                        "count": int(h.get("count", 0))},
                                  flow="step")
                        if _tel is not None and h.get("tr") is not None
                        else contextlib.nullcontext())
                    with apply_span:
                        items = decode_pytree(body)
                        chan = self._channels[h["chan"]]
                        # a journaled channel fuses the dedup watermark
                        # into the flush's own record (ONE append per
                        # frame; items + watermark atomic by
                        # construction); an unwrapped channel gets a
                        # standalone watermark append INSIDE st.lock,
                        # after the apply. Either way the remaining crash
                        # window — applied, not acked — heals on the data
                        # path: the producer replays the un-acked frame
                        # and the recovered watermark dedups it
                        # exactly-once
                        meta = (None if self._journal is None else
                                {"stream": h["stream"], "seq": seq,
                                 "window": st.window,
                                 "ack_every": st.ack_every})
                        fused = (meta is not None
                                 and hasattr(chan, "put_many_encoded"))
                        verdicts = [bool(v) for v in (
                            chan.put_many_encoded(items, body,
                                                  stream_meta=meta)
                            if fused
                            else self._apply_put(chan, items, body))]
                        st.record(seq, verdicts)
                        if meta is not None and not fused:
                            self._journal.append(
                                "stream", dict(meta, chan=h["chan"],
                                               verdicts=verdicts))
                    if _fault is not None:
                        _fault("server.stream_applied")
                    acks = (st.drain_acks()
                            if len(st.pending_acks) >= st.ack_every
                            else None)
                self.metrics.inc("stream_frames")
                self.metrics.inc("stream_items", float(len(verdicts)))
                if acks is None:
                    return None, b""          # cumulative: ack later
                return {"ok": True, "acks": acks}, b""
            if m == "chan.pop":
                got = self._channels[h["chan"]].pop_batch(
                    h["n"], timeout=h.get("timeout", 0.0))
                if got is None:
                    return {"ok": False}, b""
                return {"ok": True}, encode_pytree(got)
            if m == "chan.pop_many":
                chan = self._channels[h["chan"]]
                pop_many = getattr(chan, "pop_many", None)
                if pop_many is not None:
                    got = pop_many(h["n"], timeout=h.get("timeout", 0.0))
                else:
                    got = chan.pop_batch(
                        min(h["n"], max(len(chan), 1)),
                        timeout=h.get("timeout", 0.0))
                if got is None:
                    return {"ok": False}, b""
                return {"ok": True, "count": len(got)}, encode_pytree(got)
            if m == "chan.len":
                return {"len": len(self._channels[h["chan"]])}, b""
            if m == "chan.stats":
                return {"stats": self._channels[h["chan"]].stats()}, b""
            if m == "store.acquire":
                raw = self._store.acquire_raw(
                    newer_than=h.get("newer_than", -1),
                    timeout=h.get("timeout", 0.0))
                if raw is None:
                    return {"ok": False}, b""
                payload, version = raw
                blob = self._weights_blob(payload, version)
                if h.get("want_lane"):
                    # broadcast lane: the reply carries only the blob's
                    # POSITION in the persistent lane ring — the reader
                    # copies it out positionally (torn reads detected
                    # client-side fall back to a no_lane re-acquire)
                    info = self._lane_publish(version, blob)
                    if info is not None:
                        self.metrics.inc("weight_lane_serves")
                        return {"ok": True, "version": version,
                                **info}, b""
                return {"ok": True, "version": version}, blob
            if m == "store.state":
                return {"version": self._store.version(),
                        "draining": self._store.draining}, b""
            if m == "store.drain":
                self._store.begin_publish()
                return {"ok": True}, b""
            if m == "store.publish":
                self._store.publish(decode_pytree(body, copy=True),
                                    h["version"])
                return {"ok": True}, b""
            if m in ("infer.open", "infer.submit", "infer.result"):
                if self._infer is None:
                    return {"err": "this server hosts no inference "
                                   "plane"}, b""
                if m == "infer.open":
                    return dict(self._infer.handle_open(h)), b""
                if m == "infer.submit":
                    self.metrics.inc("infer_submits")
                    return dict(self._infer.handle_submit(h, body)), b""
                resp, rbody = self._infer.handle_result(h)
                if rbody:
                    # rides the generic reply data plane: want_ring pushes
                    # the encoded result list through the connection's
                    # ring, want_shm through a per-message segment
                    self.metrics.inc("infer_results",
                                     float(resp.get("count", 0)))
                return dict(resp), rbody
            if m == "worker.hello":
                if self._token and h.get("token") != self._token:
                    self.metrics.inc("auth_failures")
                    return {"err": "worker.hello: bad or missing token"}, b""
                if self._hello is None:
                    return {"err": "this server hosts no connect-mode "
                                   "worker slots"}, b""
                return dict(self._hello(h)), b""
            if m == "worker.report":
                host = self._sinks.get(h["worker"])
                if host is None:
                    return {"err": f"unknown worker {h['worker']!r}"}, b""
                incarnation = int(h.get("incarnation", 0))
                report = h.get("report", {})
                # child-process trace buffers ride the report; fold them
                # into this process's collector so one trace.dump (or
                # --trace-out) sees the whole process tree
                trace_events = (report.pop("trace", None)
                                if isinstance(report, dict) else None)
                if _tel is not None and trace_events:
                    _tel.extend_foreign(trace_events)
                    self.metrics.inc("trace_events_folded",
                                     float(len(trace_events)))
                host.apply_report(report, incarnation=incarnation)
                # per-incarnation stop verdict: a superseded or
                # budget-exhausted incarnation is told to exit even while
                # the slot itself lives on
                stop_for = getattr(host, "stop_for", None)
                stop = (stop_for(incarnation) if stop_for is not None
                        else host.stop_requested)
                return {"stop": bool(stop)}, b""
            if m == "server.stats":
                # counters snapshot + journal state: the chaos harness
                # asserts monotonicity across a server replacement
                snap = self.metrics.snapshot()
                stats = dict(snap.get("counters", {}))
                stats.update(snap.get("gauges", {}))
                if self._journal is not None:
                    stats.update(self._journal.stats())
                return {"ok": True, "stats": stats}, b""
            if m == "metrics.snapshot":
                # remote scrape of the whole registry: the orchestrator
                # points snapshot_provider at its TelemetrySink sample
                if self.snapshot_provider is not None:
                    return {"ok": True,
                            "sample": dict(self.snapshot_provider())}, b""
                return {"ok": True, "sample": {
                    "services": {self.name: self.metrics.snapshot()},
                    "health": {self.name: self.health()}}}, b""
            if m == "trace.dump":
                # every buffered span this process holds — including
                # child-process events folded from worker.report payloads
                if _tel is None:
                    return {"ok": True, "enabled": False, "events": []}, b""
                return {"ok": True, "enabled": True,
                        "events": _tel.drain(
                            clear=bool(h.get("clear", True)))}, b""
            if m == "ping":
                return {"ok": True}, b""
            return {"err": f"unknown method {m!r}"}, b""
        except Exception as e:  # noqa: BLE001 — fault goes back to the caller
            return {"err": f"{type(e).__name__}: {e}"}, b""

    @staticmethod
    def _apply_put(chan: Any, items: List[Any], body: bytes) -> List[Any]:
        """Route a decoded flush into ``chan``, handing a journaled
        channel the wire encoding too so it never re-encodes."""
        pme = getattr(chan, "put_many_encoded", None)
        if pme is not None:
            return pme(items, body)
        put_many = getattr(chan, "put_many", None)
        if put_many is not None:
            return put_many(items)
        return [chan.put(x) for x in items]

    # -- resilience: journal capture + recovery -------------------------------
    def _stream_records(self) -> List[Tuple[str, Dict, bytes]]:
        """Snapshot every stream's dedup state (compaction capture; safe
        to run post-rotation — watermarks are idempotent on replay)."""
        with self._streams_lock:
            states = list(self._streams.items())
        records: List[Tuple[str, Dict, bytes]] = []
        for (chan, stream), st in states:
            with st.lock:
                records.append((
                    "stream_snap",
                    {"chan": chan, "stream": stream, "seq": st.last_seq,
                     "acks": {str(k): v for k, v in st.acks.items()},
                     "window": st.window, "ack_every": st.ack_every}, b""))
        return records

    def resume_from_journal(self):
        """Adopt the journal directory's recovered state: refill hosted
        channels (without re-journaling — the items are already in the
        chain this journal continues), rebuild stream dedup watermarks so
        replayed in-flight windows dedup exactly-once, and republish the
        newest recovered weights. Call after ``add_channel``/``set_store``
        and before ``start()``. Returns the
        :class:`~repro.runtime.transport.resilience.RecoveredState`."""
        if self._journal is None:
            raise RuntimeError("resume_from_journal needs a journal")
        state = recover(self._journal.directory)
        restored_items = 0
        for name, chan in self._channels.items():
            items = state.channel_items(name)
            if not items:
                continue
            restore = getattr(chan, "restore", None)
            if restore is not None:
                restored_items += restore(items)
            else:
                restored_items += sum(bool(chan.put(x)) for x in items)
        for (cname, sid), s in state.streams.items():
            st = self._stream_state(cname, sid, s["window"], s["ack_every"])
            with st.lock:
                if s["last_seq"] > st.last_seq:
                    st.last_seq = s["last_seq"]
                for k in sorted(s["acks"]):
                    st.acks[k] = s["acks"][k]
        sp = state.store_params()
        if sp is not None and self._store is not None:
            params, version = sp
            if version > self._store.version():
                # re-publish through the store so acquirers see it AND
                # the attached on_publish hook re-journals it
                self._store.publish(params, version)
        self.metrics.inc("journal_recovered_items", float(restored_items))
        self.metrics.inc("journal_recovered_streams",
                         float(len(state.streams)))
        if state.torn_tail:
            self.metrics.inc("journal_torn_tail")
        # immediate compaction: the recovered state becomes one snapshot,
        # so the next crash replays it instead of the whole dead chain
        self._journal.compact(self._stream_records)
        return state

    def _weights_blob(self, payload: Any, version: int) -> bytes:
        with self._cache_lock:
            if self._weights_cache[0] == version:
                return self._weights_cache[1]
        params = self._store.transport.recv(payload)
        blob = encode_pytree(params)
        with self._cache_lock:
            self._weights_cache = (version, blob)
        return blob

    def _lane_publish(self, version: int, blob: bytes) -> Optional[Dict]:
        """Place ``blob`` in the broadcast lane (once per version) and
        return the positional descriptor for acquire replies — or None
        when the lane is disabled, unavailable, or too small for this
        blob (callers fall back to the socket/SHM body)."""
        if self._lane_bytes <= 0 or shared_memory is None:
            return None
        with self._lane_lock:
            if self._lane_info[0] == version:
                return self._lane_info[1]
            try:
                if self._lane is None:
                    self._lane = ShmRing.create(self._lane_bytes)
                if len(blob) > self._lane.max_record():
                    return None
                pos, seq = self._lane.publish_blob(blob)
            except (RingError, OSError):
                return None
            info = {"lane": self._lane.name, "lane_pos": int(pos),
                    "lane_seq": int(seq), "lane_nbytes": len(blob)}
            self._lane_info = (version, info)
        self.metrics.inc("weight_lane_publishes")
        self.metrics.inc("weight_lane_bytes", float(len(blob)))
        return info
