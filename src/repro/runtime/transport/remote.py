"""Remote worker processes: RemoteServiceHost (parent) + worker_main (child).

The paper's *physical isolation* claim means rollout/inference workers in
their own OS processes. The shape here keeps the service architecture
intact on both sides of the boundary:

  * the parent registers a :class:`RemoteRolloutHost` — an ordinary
    :class:`~repro.runtime.service.Service` on the bus whose job is to
    spawn, monitor, and contain ONE child process. If the child dies or
    reports an internal failure, the host raises inside its monitor
    thread, which marks it FAILED exactly like a local crash — schedulers
    fail fast instead of hanging (crash containment crosses the boundary);
  * the child (``worker_main``, always the ``spawn`` start method — never
    fork a process holding jax threads) builds a self-contained worker: a
    local :class:`~repro.runtime.inference.InferenceService` pulling
    weights through a :class:`WeightStoreTransport`, plus N
    :class:`~repro.runtime.rollout.RolloutWorker` envs pushing segments
    through a Socket/Shm channel — the D-VLA-style high-concurrency
    rollout worker with colocated inference;
  * every heartbeat the child posts a ``worker.report`` (merged metric
    snapshot + per-service health); the reply carries the stop flag, so
    shutdown is cooperative with a terminate fallback. The host mirrors
    the report into its own :class:`MetricsRegistry`
    (``apply_remote``), which is how the remote worker appears in
    ``AcceRLSystem.metrics()["services"]`` with no schema change.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig
from repro.runtime.service import Service
from repro.runtime.transport.channel import (ChannelClosed, ShmChannel,
                                             SocketChannel, TransportError,
                                             WireClient)
from repro.runtime.transport.weights import WeightStoreTransport

__all__ = ["RemoteWorkerSpec", "RemoteServiceHost", "RemoteRolloutHost",
           "worker_main"]


@dataclasses.dataclass
class RemoteWorkerSpec:
    """Everything a spawned child needs — plain picklable data only (no
    callables: env latency travels as (mean_ms, sigma), not a closure)."""

    name: str
    cfg: ModelConfig
    rl: RLConfig
    rt: RuntimeConfig
    address: Tuple[str, int]
    kind: str = "rollout"
    channel: str = "experience"
    frame_channel: Optional[str] = None
    suite: str = "spatial"
    segment_horizon: int = 8
    max_episode_steps: int = 30
    num_envs: int = 1
    seed: int = 0
    use_shm: bool = False
    shm_threshold: int = 1 << 16
    connect_timeout_s: float = 20.0
    latency_mean_ms: Optional[float] = None
    latency_sigma: float = 1.0
    heartbeat_s: float = 0.25
    temperature: float = 1.0


# ---------------------------------------------------------------------------
# child side
# ---------------------------------------------------------------------------

def _merge_snapshots(snaps: List[Dict]) -> Dict:
    """Fold per-service snapshots into one: counters sum, gauges last-wins,
    series summaries combine count-weighted."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    series: Dict[str, Dict] = {}
    for snap in snaps:
        for k, v in snap.get("counters", {}).items():
            counters[k] = counters.get(k, 0.0) + v
        gauges.update(snap.get("gauges", {}))
        for k, s in snap.get("series", {}).items():
            cur = series.setdefault(k, {"count": 0, "mean": 0.0,
                                        "last": 0.0})
            total = cur["count"] + s["count"]
            if s["count"]:
                cur["mean"] = (cur["mean"] * cur["count"]
                               + s["mean"] * s["count"]) / total
                cur["count"] = total
                cur["last"] = s["last"]
    return {"counters": counters, "gauges": gauges, "series": series}


def _build_report(services: List[Service]) -> Dict:
    healthy = all(s.error is None for s in services)
    first_error = next((repr(s.error) for s in services
                        if s.error is not None), None)
    return {
        "health": {"healthy": healthy,
                   "state": "failed" if not healthy else "running",
                   "error": first_error},
        "services": {s.name: {"health": s.health(),
                              "metrics": s.metrics.snapshot()}
                     for s in services},
        "merged": _merge_snapshots([s.metrics.snapshot()
                                    for s in services]),
    }


def worker_main(spec: RemoteWorkerSpec) -> int:
    """Child-process entry: build the remote service set, run it, report.

    Returns the exit code (0 clean stop, 3 internal service failure).
    Heavy imports live here, not at module scope — the parent never pays
    for them and the child initializes its own jax runtime.
    """
    from repro.envs.toy_manipulation import TASKS_PER_SUITE, lognormal_latency
    from repro.core.resampler import DynamicWeightedResampler
    from repro.runtime.inference import InferenceService
    from repro.runtime.rollout import RolloutWorker

    Channel = ShmChannel if spec.use_shm else SocketChannel
    experience = Channel(spec.address, spec.channel,
                         connect_timeout=spec.connect_timeout_s,
                         shm_threshold=spec.shm_threshold)
    frames = (Channel(spec.address, spec.frame_channel,
                      connect_timeout=spec.connect_timeout_s,
                      shm_threshold=spec.shm_threshold)
              if spec.frame_channel else None)
    store = WeightStoreTransport(spec.address, use_shm=spec.use_shm,
                                 connect_timeout=spec.connect_timeout_s,
                                 shm_threshold=spec.shm_threshold)
    control = WireClient(spec.address,
                         connect_timeout=spec.connect_timeout_s)

    latency = (lognormal_latency(spec.latency_mean_ms,
                                 sigma=spec.latency_sigma, seed=spec.seed)
               if spec.latency_mean_ms else None)
    # task selection is resampled locally per child — each process keeps
    # its own success history (no cross-process resampler sync)
    resampler = DynamicWeightedResampler(TASKS_PER_SUITE, seed=spec.seed)
    inference = InferenceService(spec.cfg, store, spec.rt,
                                 temperature=spec.temperature,
                                 seed=spec.seed)
    workers = [
        RolloutWorker(i, spec.cfg, inference, experience, suite=spec.suite,
                      resampler=resampler,
                      segment_horizon=spec.segment_horizon,
                      max_steps=spec.max_episode_steps, latency=latency,
                      seed=spec.seed * 1000 + i, frame_channel=frames)
        for i in range(spec.num_envs)
    ]
    services: List[Service] = [inference] + list(workers)
    for s in services:
        s.start()

    exit_code = 0
    try:
        while True:
            report = _build_report(services)
            try:
                resp, _ = control.request({"m": "worker.report",
                                           "worker": spec.name,
                                           "report": report})
            except (TransportError, ChannelClosed):
                break                       # parent gone — shut down
            if resp.get("stop"):
                break
            if not report["health"]["healthy"]:
                exit_code = 3               # parent saw the report; die loud
                break
            time.sleep(spec.heartbeat_s)
    finally:
        for s in reversed(services):
            s.stop()
        for s in services:
            s.join(timeout=5.0)
        try:                                # best-effort final numbers
            control.request({"m": "worker.report", "worker": spec.name,
                             "report": _build_report(services)})
        except (TransportError, ChannelClosed):
            pass
        for closable in (experience, frames, store, control):
            if closable is not None:
                closable.close()
    return exit_code


def _child_entry(spec: RemoteWorkerSpec) -> None:
    sys.exit(worker_main(spec))


# ---------------------------------------------------------------------------
# parent side
# ---------------------------------------------------------------------------

class RemoteServiceHost(Service):
    """Parent-side handle for one spawned worker process.

    Lifecycle mapping: ``start`` spawns the child, the service thread is a
    liveness monitor, ``stop`` raises the cooperative stop flag (delivered
    in the next ``worker.report`` reply), ``join`` waits for the process
    with a terminate → kill escalation so shutdown can never hang.
    """

    def __init__(self, spec: RemoteWorkerSpec, server, *,
                 role: str = "rollout"):
        super().__init__(spec.name, role=role)
        self.spec = spec
        self.server = server
        server.register_worker_sink(spec.name, self)
        self.process: Optional[multiprocessing.process.BaseProcess] = None
        self._stop_remote = False
        self._remote_error: Optional[str] = None
        self.reports_seen = 0
        self.remote_health: Dict = {}
        self.remote_services: Dict = {}

    # -- report sink (called from a server connection thread) -----------------
    @property
    def stop_requested(self) -> bool:
        return self._stop_remote or self._stop.is_set()

    def apply_report(self, report: Dict) -> None:
        self.remote_health = report.get("health", {})
        self.remote_services = report.get("services", {})
        self.metrics.apply_remote(report.get("merged", {}))
        self.reports_seen += 1
        if not self.remote_health.get("healthy", True):
            self._remote_error = (self.remote_health.get("error")
                                  or "remote service failed")

    # -- lifecycle ------------------------------------------------------------
    def on_start(self) -> None:
        ctx = multiprocessing.get_context("spawn")
        self.process = ctx.Process(target=_child_entry, args=(self.spec,),
                                   name=self.name, daemon=True)
        self.process.start()

    def _run(self) -> None:
        proc = self.process
        while not self._stop.is_set():
            if self._remote_error is not None:
                raise RuntimeError(
                    f"remote worker {self.name!r} reported a failed "
                    f"service: {self._remote_error}")
            if proc is not None and not proc.is_alive():
                if self.stop_requested:
                    break
                raise RuntimeError(
                    f"remote worker {self.name!r} process died "
                    f"(exitcode={proc.exitcode})")
            time.sleep(0.05)

    def on_stop(self) -> None:
        self._stop_remote = True

    def join(self, timeout: float = 5.0) -> None:
        proc = self.process
        if proc is not None:
            proc.join(timeout=timeout)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=2.0)
            if proc.is_alive():            # pragma: no cover — last resort
                proc.kill()
                proc.join(timeout=2.0)
        super().join(timeout=1.0)


class RemoteRolloutHost(RemoteServiceHost):
    """Rollout-flavored host: mirrors the counters the orchestrator
    aggregates across rollout workers, so a remote worker contributes to
    ``env_steps`` / ``episodes`` / ``success_rate`` / ``mean_return``
    exactly like a local one."""

    def __init__(self, spec: RemoteWorkerSpec, server):
        super().__init__(spec, server, role="rollout")

    @property
    def env_steps(self) -> int:
        return int(self.metrics.counter("env_steps"))

    @property
    def episodes_done(self) -> int:
        return int(self.metrics.counter("episodes"))

    @property
    def successes(self) -> int:
        return int(self.metrics.counter("successes"))

    @property
    def returns(self) -> List[float]:
        s = self.metrics.snapshot()["series"].get("return")
        if not s or not s["count"]:
            return []
        # the child ships a count/mean summary; expanding it preserves the
        # count-weighted global mean the orchestrator computes
        return [s["mean"]] * int(s["count"])
