"""Remote worker process body: ``worker_main`` + its picklable spec.

The paper's *physical isolation* claim means rollout/inference workers in
their own OS processes. This module is the CHILD side of that boundary —
a self-contained worker (local :class:`~repro.runtime.inference.InferenceService`
pulling weights through a :class:`WeightStoreTransport`, plus N
:class:`~repro.runtime.rollout.RolloutWorker` envs pushing segments
through a Socket/Shm channel) that heartbeats ``worker.report`` frames
back to the parent. How such a worker *comes to exist* and how it is
*supervised* live in :mod:`repro.runtime.transport.supervision`:

  * a :class:`~repro.runtime.transport.supervision.SpawnedEndpoint` runs
    ``worker_main`` in a ``spawn``-start-method child (never fork a
    process holding jax threads);
  * a :class:`~repro.runtime.transport.supervision.ConnectedEndpoint`
    waits for the SAME body to dial in from anywhere — the
    ``repro.launch.worker`` CLI performs the ``worker.hello`` token
    handshake, receives its spec over the wire (``spec_from_wire``), and
    calls ``worker_main``. One worker body, two lifecycles.

Every heartbeat carries the worker's *incarnation* id, so a restarted
worker's reports are distinguishable from its dead predecessor's: the
parent slot drops stale-incarnation reports (idempotent bridging) and the
report *reply* tells a superseded incarnation to stop.
"""
from __future__ import annotations

import dataclasses
import os
import random
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.configs.base import (HybridConfig, ModelConfig, MoEConfig,
                                RLConfig, RuntimeConfig, SSMConfig,
                                SupervisionConfig, TelemetryConfig,
                                TransportConfig)
from repro.runtime.service import Service, _hist_merge

# Tracing is import-gated exactly like transport.faults: when REPRO_TRACE is
# unset the telemetry module is never imported and child spans ride nowhere.
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path, asserted import-inert in tests
    _tel = None
from repro.runtime.transport.channel import (ChannelClosed, ShmChannel,
                                             SocketChannel, TransportError,
                                             WireClient)
from repro.runtime.transport.weights import WeightStoreTransport

__all__ = ["RemoteWorkerSpec", "worker_main", "spec_to_wire",
           "spec_from_wire"]


@dataclasses.dataclass
class RemoteWorkerSpec:
    """Everything a remote worker needs — plain picklable data only (no
    callables: env latency travels as (mean_ms, sigma), not a closure).
    Also JSON-serializable via ``spec_to_wire`` so connect-mode workers
    can receive it over the ``worker.hello`` handshake."""

    name: str
    cfg: ModelConfig
    rl: RLConfig
    rt: RuntimeConfig
    address: Tuple[str, int]
    kind: str = "rollout"             # {"rollout", "inference"}
    channel: str = "experience"
    frame_channel: Optional[str] = None
    suite: str = "spatial"
    segment_horizon: int = 8
    max_episode_steps: int = 30
    num_envs: int = 1
    seed: int = 0
    use_shm: bool = False
    # streaming data plane: use_ring routes segments through persistent
    # SHM rings (ShmRingChannel); put_window > 0 pipelines flushes
    # through a windowed-ack PutStream (works with any channel kind)
    use_ring: bool = False
    ring_bytes: int = 8 << 20
    put_window: int = 0
    # adaptive streaming: the PutStream tunes its effective window / ack
    # cadence online from observed ack RTT; put_window stays the upper bound
    adaptive_window: bool = False
    # weight broadcast lane: the parent advertises blob positions in its
    # persistent lane ring and this worker reads them positionally
    # (same-host fan-out without per-acquire SHM segments)
    use_weight_lane: bool = False
    shm_threshold: int = 1 << 16
    connect_timeout_s: float = 20.0
    latency_mean_ms: Optional[float] = None
    latency_sigma: float = 1.0
    heartbeat_s: float = 0.25
    temperature: float = 1.0
    # supervision: which incarnation of its slot this worker is — echoed
    # in every report so the parent can drop stale reports and stop
    # superseded workers
    incarnation: int = 0
    token: str = ""
    # wire-client resilience: transparent redial budget after a
    # server-side connection drop (0 = fail fast, PR 3 behavior)
    reconnect_attempts: int = 0
    reconnect_backoff_s: float = 0.1
    # -- disaggregated inference plane ---------------------------------------
    # rollout children: inference="remote" swaps the colocated
    # InferenceService for a RemoteInferenceClient dialing infer_address
    # (the parent server in host mode, the tier child in spawn mode).
    # kind="inference" children: infer_listen is the FIXED bind address of
    # the tier's own TransportServer — baked into the spec so a supervised
    # restart rebinds the same port and workers redial transparently.
    inference: str = "local"          # {"local", "remote"}
    infer_address: Optional[Tuple[str, int]] = None
    infer_listen: Optional[Tuple[str, int]] = None


# ---------------------------------------------------------------------------
# spec <-> wire (the worker.hello reply carries the spec as plain JSON)
# ---------------------------------------------------------------------------

def spec_to_wire(spec: RemoteWorkerSpec) -> Dict:
    """Flatten a spec into JSON-safe nested dicts (tuples become lists on
    the wire; ``spec_from_wire`` restores them)."""
    return dataclasses.asdict(spec)


def spec_from_wire(wire: Dict) -> RemoteWorkerSpec:
    """Rebuild a :class:`RemoteWorkerSpec` from its wire dict."""
    d = dict(wire)
    cfg = dict(d["cfg"])
    for key, cls in (("moe", MoEConfig), ("ssm", SSMConfig),
                     ("hybrid", HybridConfig)):
        if cfg.get(key) is not None:
            cfg[key] = cls(**cfg[key])
    d["cfg"] = ModelConfig(**cfg)
    d["rl"] = RLConfig(**d["rl"])
    rt = dict(d["rt"])
    transport = dict(rt["transport"])
    transport["supervision"] = SupervisionConfig(**transport["supervision"])
    rt["transport"] = TransportConfig(**transport)
    if rt.get("telemetry") is not None:
        rt["telemetry"] = TelemetryConfig(**rt["telemetry"])
    rt["batch_buckets"] = tuple(rt["batch_buckets"])
    d["rt"] = RuntimeConfig(**rt)
    d["address"] = (str(d["address"][0]), int(d["address"][1]))
    for key in ("infer_address", "infer_listen"):
        if d.get(key) is not None:
            d[key] = (str(d[key][0]), int(d[key][1]))
    return RemoteWorkerSpec(**d)


# ---------------------------------------------------------------------------
# child side
# ---------------------------------------------------------------------------

def _merge_snapshots(snaps: List[Dict]) -> Dict:
    """Fold per-service snapshots into one: counters sum, gauges last-wins,
    series summaries combine count-weighted, histograms add bucketwise."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    series: Dict[str, Dict] = {}
    hists: Dict[str, Dict] = {}
    for snap in snaps:
        for k, v in snap.get("counters", {}).items():
            counters[k] = counters.get(k, 0.0) + v
        gauges.update(snap.get("gauges", {}))
        for k, s in snap.get("series", {}).items():
            cur = series.setdefault(k, {"count": 0, "mean": 0.0,
                                        "last": 0.0})
            total = cur["count"] + s["count"]
            if s["count"]:
                cur["mean"] = (cur["mean"] * cur["count"]
                               + s["mean"] * s["count"]) / total
                cur["count"] = total
                cur["last"] = s["last"]
        for k, h in snap.get("hists", {}).items():
            hists[k] = _hist_merge(hists.get(k), h)
    return {"counters": counters, "gauges": gauges, "series": series,
            "hists": hists}


def _build_report(services: List[Service]) -> Dict:
    healthy = all(s.error is None for s in services)
    first_error = next((repr(s.error) for s in services
                        if s.error is not None), None)
    report = {
        "health": {"healthy": healthy,
                   "state": "failed" if not healthy else "running",
                   "error": first_error},
        "services": {s.name: {"health": s.health(),
                              "metrics": s.metrics.snapshot()}
                     for s in services},
        "merged": _merge_snapshots([s.metrics.snapshot()
                                    for s in services]),
    }
    if _tel is not None:
        # Child-side spans ride the heartbeat; the TransportServer folds
        # them into its foreign buffer so one trace.dump covers every pid.
        events = _tel.drain()
        if events:
            report["trace"] = events
    return report


def _report_once(spec: RemoteWorkerSpec, control: WireClient,
                 services: List[Service]) -> Dict:
    report = _build_report(services)
    resp, _ = control.request({"m": "worker.report",
                               "worker": spec.name,
                               "incarnation": spec.incarnation,
                               "report": report})
    return {"report": report, "resp": resp}


def _heartbeat_loop(spec: RemoteWorkerSpec, control: WireClient,
                    services: List[Service]) -> int:
    """Shared child report loop (rollout and inference-tier children):
    heartbeat until the parent says stop, the wire dies, or a local
    service fails. Returns the exit code."""
    while True:
        try:
            got = _report_once(spec, control, services)
        except (TransportError, ChannelClosed):
            return 0                        # parent gone — shut down
        if got["resp"].get("stop"):
            return 0
        if not got["report"]["health"]["healthy"]:
            return 3                        # parent saw the report; die loud
        # ±25% jitter: N workers' heartbeats (and their redials after
        # a server replacement) decorrelate instead of arriving as
        # one synchronized burst per period
        time.sleep(spec.heartbeat_s * (0.75 + 0.5 * random.random()))


def worker_main(spec: RemoteWorkerSpec) -> int:
    """Remote-worker entry: build the service set, run it, report.

    ``spec.kind`` selects the body: ``"rollout"`` (env workers, with a
    colocated OR remote inference pool per ``spec.inference``) or
    ``"inference"`` (the shared inference tier). Returns the exit code
    (0 clean stop, 3 internal service failure). Heavy imports live here,
    not at module scope — the parent never pays for them and the child
    initializes its own jax runtime.
    """
    if spec.kind == "inference":
        return _inference_plane_main(spec)
    from repro.envs.toy_manipulation import TASKS_PER_SUITE, lognormal_latency
    from repro.core.resampler import DynamicWeightedResampler
    from repro.runtime.inference import InferenceService
    from repro.runtime.rollout import RolloutWorker

    from repro.runtime.transport.channel import ShmRingChannel

    wire_kw = dict(connect_timeout=spec.connect_timeout_s,
                   reconnect_attempts=spec.reconnect_attempts,
                   reconnect_backoff_s=spec.reconnect_backoff_s,
                   shm_threshold=spec.shm_threshold)
    if spec.use_ring:
        Channel = ShmRingChannel
        chan_kw = dict(wire_kw, ring_bytes=spec.ring_bytes,
                       put_window=(spec.put_window or 32),
                       adaptive_window=spec.adaptive_window)
    else:
        Channel = ShmChannel if spec.use_shm else SocketChannel
        chan_kw = dict(wire_kw, put_window=spec.put_window,
                       adaptive_window=spec.adaptive_window)
    experience = Channel(spec.address, spec.channel, **chan_kw)
    frames = (Channel(spec.address, spec.frame_channel, **chan_kw)
              if spec.frame_channel else None)
    control = WireClient(spec.address,
                         connect_timeout=spec.connect_timeout_s,
                         reconnect_attempts=spec.reconnect_attempts,
                         reconnect_backoff_s=spec.reconnect_backoff_s)

    store = None
    if spec.inference == "remote":
        # disaggregated plane: action requests go to the shared tier; no
        # local pool, no local weight wire (the tier owns the weights)
        from repro.runtime.transport.inference_plane import \
            RemoteInferenceClient
        inference = RemoteInferenceClient(
            tuple(spec.infer_address or spec.address),
            client_id=spec.name,
            connect_timeout=spec.connect_timeout_s,
            shm_threshold=spec.shm_threshold,
            reconnect_attempts=spec.reconnect_attempts,
            reconnect_backoff_s=spec.reconnect_backoff_s,
            use_ring=spec.use_ring)
        services: List[Service] = []
    else:
        # the weight wire either rides the per-message SHM path or (with
        # use_weight_lane) reads blobs positionally out of the parent's
        # persistent broadcast lane ring — one publish serves N same-host
        # readers with zero per-acquire segment churn
        store = WeightStoreTransport(
            spec.address, use_shm=spec.use_shm or spec.use_ring,
            shm_threshold=spec.shm_threshold,
            connect_timeout=spec.connect_timeout_s,
            reconnect_attempts=spec.reconnect_attempts,
            reconnect_backoff_s=spec.reconnect_backoff_s,
            use_lane=spec.use_weight_lane)
        inference = InferenceService(spec.cfg, store, spec.rt,
                                     temperature=spec.temperature,
                                     seed=spec.seed)
        services = [inference]

    latency = (lognormal_latency(spec.latency_mean_ms,
                                 sigma=spec.latency_sigma, seed=spec.seed)
               if spec.latency_mean_ms else None)
    # task selection is resampled locally per child — each process keeps
    # its own success history (no cross-process resampler sync)
    resampler = DynamicWeightedResampler(TASKS_PER_SUITE, seed=spec.seed)
    workers = [
        RolloutWorker(i, spec.cfg, inference, experience, suite=spec.suite,
                      resampler=resampler,
                      segment_horizon=spec.segment_horizon,
                      max_steps=spec.max_episode_steps, latency=latency,
                      seed=spec.seed * 1000 + i, frame_channel=frames)
        for i in range(spec.num_envs)
    ]
    services = services + list(workers)
    for s in services:
        s.start()

    try:
        exit_code = _heartbeat_loop(spec, control, services)
    finally:
        for s in reversed(services):
            s.stop()
        for s in services:
            s.join(timeout=5.0)
        try:                                # best-effort final numbers
            _report_once(spec, control, services)
        except (TransportError, ChannelClosed):
            pass
        closables = [experience, frames, store, control]
        if spec.inference == "remote":
            closables.append(inference)
        for closable in closables:
            if closable is not None:
                closable.close()
    return exit_code


def _inference_plane_main(spec: RemoteWorkerSpec) -> int:
    """Inference-tier child: the shared pool + broker behind its own
    fixed-address ``TransportServer``, weights pulled from the parent."""
    from repro.runtime.transport.inference_plane import InferencePlaneService

    control = WireClient(spec.address,
                         connect_timeout=spec.connect_timeout_s,
                         reconnect_attempts=spec.reconnect_attempts,
                         reconnect_backoff_s=spec.reconnect_backoff_s)
    plane = InferencePlaneService(
        spec.cfg, spec.rt, spec.address,
        listen=tuple(spec.infer_listen or ("127.0.0.1", 0)),
        temperature=spec.temperature, seed=spec.seed,
        use_shm=spec.use_shm or spec.use_ring,
        shm_threshold=spec.shm_threshold,
        connect_timeout=spec.connect_timeout_s,
        reconnect_attempts=spec.reconnect_attempts,
        reconnect_backoff_s=spec.reconnect_backoff_s, token=spec.token)
    plane.start()
    # the pool reports alongside the plane so its eq.-1 window counters
    # (batches, padded_slots, degenerate_batches) bridge to the parent
    services: List[Service] = [plane, plane.pool]
    try:
        exit_code = _heartbeat_loop(spec, control, services)
    finally:
        plane.stop()
        plane.join(timeout=5.0)
        try:
            _report_once(spec, control, services)
        except (TransportError, ChannelClosed):
            pass
        control.close()
    return exit_code


def _child_entry(spec: RemoteWorkerSpec) -> None:
    sys.exit(worker_main(spec))
