"""FaultPlan: deterministic fault injection for the transport layer.

Chaos tests must *prove* the recovery invariants (journal replay is
exactly-once, workers redial, torn ring tails are discarded) rather than
hope a wall-clock race hits the window. This module injects faults at
named points in the server/client hot paths, at deterministic hit counts,
configured entirely through one environment variable:

    REPRO_FAULTS="kill@server.stream_applied:nth=40;delay@server.frame:every=8,ms=20"

Grammar — ``;``-separated directives, each ``kind@point[:k=v[,k=v...]]``:

  ==========  =============================================================
  ``reset``   raise :class:`InjectedReset` (a ``ConnectionResetError``):
              the surrounding connection handler treats it as the peer
              vanishing — exercises redial/replay paths
  ``delay``   sleep ``ms`` milliseconds (default 50): delayed acks,
              heartbeat jitter, slow-consumer windows
  ``torn``    raise :class:`InjectedTorn` (a
              :class:`~repro.runtime.transport.ring.RingError`): at the
              ring commit point this leaves a reserved-but-uncommitted
              record — the torn tail :meth:`ShmRing.recover` discards
  ``kill``    ``SIGKILL`` the current process — the real crash the
              journal/resume machinery exists for
  ==========  =============================================================

Trigger args: ``nth=K`` fires on exactly the K-th hit of the point (once);
``every=N`` fires on every N-th hit; ``prob=P`` fires each hit with
probability P from a per-point deterministic stream (``seed=S``, default
0 — same spec, same decisions, every run). Default with no args: every
hit.

**Inertness.** Hot modules gate the import itself::

    if os.environ.get("REPRO_FAULTS"):
        from repro.runtime.transport.faults import fault_point as _fault
    else:
        _fault = None

so with the gate off this module is never imported (tests assert it is
absent from ``sys.modules``) and every fault site costs one ``is None``
check.
"""
from __future__ import annotations

import os
import random
import signal
import threading
import time
from typing import Dict, List, Optional

from repro.runtime.transport.ring import RingError

__all__ = ["FaultError", "InjectedReset", "InjectedTorn", "FaultRule",
           "FaultPlan", "fault_point", "reset_plan"]

ENV_VAR = "REPRO_FAULTS"
KINDS = ("reset", "delay", "torn", "kill")


class FaultError(RuntimeError):
    """Base for injected faults (never raised itself)."""


class InjectedReset(ConnectionResetError):
    """Injected connection reset — caught by every ``OSError`` handler
    on the transport data path, exactly like a real peer death."""


class InjectedTorn(RingError):
    """Injected ring failure — raised BEFORE the commit-offset store, so
    the reserved record stays uncommitted (a torn write)."""


class FaultRule:
    """One parsed directive: a kind, a point, and a trigger."""

    __slots__ = ("kind", "point", "nth", "every", "prob", "delay_ms",
                 "_rng", "fired")

    def __init__(self, kind: str, point: str, args: Dict[str, str],
                 seed: int):
        if kind not in KINDS:
            raise ValueError(f"unknown fault kind {kind!r} (not in {KINDS})")
        self.kind = kind
        self.point = point
        self.nth = int(args["nth"]) if "nth" in args else 0
        self.every = int(args["every"]) if "every" in args else 0
        self.prob = float(args["prob"]) if "prob" in args else 0.0
        self.delay_ms = float(args.get("ms", 50.0))
        # per-rule deterministic stream: same spec -> same decisions
        self._rng = random.Random(f"{seed}:{kind}@{point}")
        self.fired = 0

    def should_fire(self, hit: int) -> bool:
        if self.nth:
            return hit == self.nth
        if self.every:
            return hit % self.every == 0
        if self.prob:
            return self._rng.random() < self.prob
        return True


def _parse(spec: str, *, seed: int = 0) -> List[FaultRule]:
    rules: List[FaultRule] = []
    for directive in spec.split(";"):
        directive = directive.strip()
        if not directive:
            continue
        head, _, argstr = directive.partition(":")
        kind, sep, point = head.partition("@")
        if not sep or not point:
            raise ValueError(f"bad fault directive {directive!r} "
                             f"(want kind@point[:k=v,...])")
        args: Dict[str, str] = {}
        for kv in argstr.split(","):
            kv = kv.strip()
            if not kv:
                continue
            k, sep, v = kv.partition("=")
            if not sep:
                raise ValueError(f"bad fault arg {kv!r} in {directive!r}")
            args[k.strip()] = v.strip()
        rules.append(FaultRule(kind.strip(), point.strip(), args,
                               int(args.get("seed", seed))))
    return rules


class FaultPlan:
    """The parsed plan: per-point hit counters + the rules they trigger."""

    def __init__(self, rules: List[FaultRule]):
        self._rules: Dict[str, List[FaultRule]] = {}
        for r in rules:
            self._rules.setdefault(r.point, []).append(r)
        self._hits: Dict[str, int] = {}
        self._lock = threading.Lock()

    @classmethod
    def from_spec(cls, spec: str, *, seed: int = 0) -> "FaultPlan":
        return cls(_parse(spec, seed=seed))

    @classmethod
    def from_env(cls) -> "FaultPlan":
        return cls.from_spec(os.environ.get(ENV_VAR, ""))

    def hit(self, point: str) -> None:
        """Register one pass through ``point``; fire any matching rule."""
        with self._lock:
            hit = self._hits[point] = self._hits.get(point, 0) + 1
            rules = self._rules.get(point, ())
            fire = [r for r in rules if r.should_fire(hit)]
            for r in fire:
                r.fired += 1
        for r in fire:
            self._execute(r)

    def _execute(self, rule: FaultRule) -> None:
        if rule.kind == "delay":
            time.sleep(rule.delay_ms / 1e3)
        elif rule.kind == "reset":
            raise InjectedReset(
                f"injected reset at {rule.point} (hit "
                f"{self._hits.get(rule.point)})")
        elif rule.kind == "torn":
            raise InjectedTorn(f"injected torn write at {rule.point}")
        elif rule.kind == "kill":          # pragma: no cover — kills us
            os.kill(os.getpid(), signal.SIGKILL)

    def snapshot(self) -> Dict[str, Dict[str, int]]:
        """Hit/fire counts per point (test observability)."""
        with self._lock:
            out: Dict[str, Dict[str, int]] = {}
            for point, hits in self._hits.items():
                out[point] = {"hits": hits,
                              "fired": sum(r.fired for r in
                                           self._rules.get(point, ()))}
            for point, rules in self._rules.items():
                out.setdefault(point, {"hits": 0, "fired": 0})
            return out


_plan: Optional[FaultPlan] = None
_plan_lock = threading.Lock()


def fault_point(point: str) -> None:
    """The module-level injection hook the gated hot paths call. Builds
    the plan from :data:`ENV_VAR` on first use."""
    global _plan
    plan = _plan
    if plan is None:
        with _plan_lock:
            if _plan is None:
                _plan = FaultPlan.from_env()
            plan = _plan
    plan.hit(point)


def reset_plan() -> None:
    """Drop the cached plan (tests that mutate the env var)."""
    global _plan
    with _plan_lock:
        _plan = None
