"""Persistent shared-memory ring buffer: the streaming data plane.

The per-message SHM path (:class:`~repro.runtime.transport.channel.ShmChannel`)
pays a ``shm_open`` + ``mmap`` + ``unlink`` syscall trio for every payload
and forces the server to keep an LRU of orphan segment names. A
:class:`ShmRing` replaces that churn with ONE segment per channel
direction, created at connect time and reused for every record — payloads
cross the boundary at memcpy speed and the only thing the server ever has
to sweep is the ring itself.

Layout (one 64-byte header cacheline, then ``capacity`` data bytes)::

    0   8s  magic "ACRLRNG1"
    8   u64 capacity                (data bytes; multiple of 16)
    16  u64 write   — RESERVE offset: monotone byte offset the producer
                      has claimed (advanced BEFORE the payload memcpy)
    24  u64 commit  — COMMIT offset: records below it are fully written;
                      the consumer never reads past it (torn-write guard)
    32  u64 read    — consumer offset (monotone)
    40  u64 items_committed
    48  u64 items_read
    56  u64 torn_discards          (recover() bumps it per discarded tail)

Records are contiguous — ``[u64 seq | u32 nbytes | u32 flags | payload]``
padded to 8 bytes. A record that would straddle the end of the data area
is preceded by a WRAP marker (``nbytes = 0xFFFFFFFF``) and restarts at
offset 0; a tail shorter than a record header is skipped implicitly by
both sides. Offsets are monotone (never wrapped), so ``free = capacity -
(write - read)`` with no ambiguity between full and empty.

Torn-write protection is the two-offset header: the producer publishes
``write`` (reserve) before the memcpy and ``commit`` only after it, so a
producer dying mid-copy leaves ``write > commit`` — the consumer never
sees the partial record, and the next producer to take over the ring
calls :meth:`recover` to discard the uncommitted tail. Each record also
carries its sequence number (``items_committed`` at reserve time); a
mismatch against ``items_read`` on the consumer side means the ring was
corrupted and raises :class:`RingError` instead of yielding garbage.

Discipline: single producer, single consumer (one process each side) —
exactly the shape of one transport connection. Both sides may live in
the same process (tests, benchmarks).
"""
from __future__ import annotations

import os
import struct
import time
from typing import Dict, Optional

try:
    from multiprocessing import shared_memory
except ImportError:  # pragma: no cover — stdlib on every target platform
    shared_memory = None

MAGIC = b"ACRLRNG1"
HEADER_SIZE = 64
RECORD_HEADER = struct.Struct("<QII")          # seq, nbytes, flags
WRAP = 0xFFFFFFFF                              # nbytes sentinel: skip to 0

_U64 = struct.Struct("<Q")
_OFF_CAPACITY = 8
_OFF_WRITE = 16
_OFF_COMMIT = 24
_OFF_READ = 32
_OFF_ITEMS_COMMITTED = 40
_OFF_ITEMS_READ = 48
_OFF_TORN = 56

#: polling granularity of blocking push/pop waits — the ring is a hot
#: path, so the sleep is short; close()/deadlines bound every wait
POLL_S = 0.0005

__all__ = ["RingError", "ShmRing", "MAGIC", "HEADER_SIZE", "WRAP"]


class RingError(RuntimeError):
    """Structural ring failure: bad magic, oversized record, corruption."""


# import-gated fault injection (see transport.faults): inert — not even
# imported — unless REPRO_FAULTS is set. The gate sits below RingError
# because faults.py imports it from this (then partially-initialized)
# module.
if os.environ.get("REPRO_FAULTS"):
    from repro.runtime.transport.faults import fault_point as _fault
else:
    _fault = None


def _pad8(n: int) -> int:
    return (n + 7) & ~7


class ShmRing:
    """Single-producer single-consumer byte ring over one SHM segment."""

    def __init__(self, shm: "shared_memory.SharedMemory", *, created: bool):
        self._shm = shm
        self.created = created
        self.closed = False
        buf = shm.buf
        if bytes(buf[:8]) != MAGIC:
            raise RingError(f"bad ring magic in segment {shm.name!r}")
        self.capacity = _U64.unpack_from(buf, _OFF_CAPACITY)[0]
        if HEADER_SIZE + self.capacity > len(buf):
            raise RingError(f"ring segment {shm.name!r} truncated")

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, capacity: int, name: Optional[str] = None) -> "ShmRing":
        """Create a fresh ring with at least ``capacity`` data bytes."""
        if shared_memory is None:
            raise RingError("shared memory unavailable on this platform")
        capacity = max(_pad8(capacity), 4 * RECORD_HEADER.size)
        capacity = (capacity + 15) & ~15               # multiple of 16
        if name is None:
            # default to the sweepable acrl<pid>x… scheme so a later
            # server incarnation can reclaim rings a SIGKILL leaked
            from repro.runtime.transport.resilience import shm_name
            while True:
                try:
                    shm = shared_memory.SharedMemory(
                        create=True, size=HEADER_SIZE + capacity,
                        name=shm_name())
                    break
                except FileExistsError:    # 32-bit token collision
                    continue
        else:
            shm = shared_memory.SharedMemory(
                create=True, size=HEADER_SIZE + capacity, name=name)
        shm.buf[:HEADER_SIZE] = bytes(HEADER_SIZE)     # zero all offsets
        shm.buf[:8] = MAGIC
        _U64.pack_into(shm.buf, _OFF_CAPACITY, capacity)
        return cls(shm, created=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRing":
        """Attach to a ring created by the peer (no unlink duty)."""
        if shared_memory is None:
            raise RingError("shared memory unavailable on this platform")
        return cls(shared_memory.SharedMemory(name=name), created=False)

    @property
    def name(self) -> str:
        return self._shm.name

    # -- header accessors (each field is ONE aligned u64 write: no tearing
    # across fields, and an 8-byte aligned store is atomic on every target)
    def _get(self, off: int) -> int:
        return _U64.unpack_from(self._shm.buf, off)[0]

    def _set(self, off: int, value: int) -> None:
        _U64.pack_into(self._shm.buf, off, value)

    # -- producer -------------------------------------------------------------
    def max_record(self) -> int:
        """Largest payload a push can ever carry (sized so one record plus
        its worst-case wrap skip always fits an empty ring)."""
        return self.capacity // 2 - RECORD_HEADER.size

    def reserve(self, nbytes: int,
                timeout: Optional[float] = None) -> Optional[memoryview]:
        """Claim space for one ``nbytes`` record; returns a writable view
        of the payload area (None on timeout). The reservation is
        published BEFORE the caller copies — :meth:`commit` makes it
        visible to the consumer; an uncommitted reservation is what
        :meth:`recover` discards."""
        if self.closed:
            return None
        if nbytes > self.max_record():
            raise RingError(f"record of {nbytes} bytes exceeds ring "
                            f"max {self.max_record()} (capacity "
                            f"{self.capacity})")
        need = RECORD_HEADER.size + _pad8(nbytes)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        buf = self._shm.buf
        while True:
            if self.closed:
                return None
            write = self._get(_OFF_WRITE)
            pos = write % self.capacity
            rem = self.capacity - pos
            if rem < RECORD_HEADER.size:
                skip, marker = rem, False          # implicit tail skip
            elif rem < need:
                skip, marker = rem, True           # WRAP marker, restart at 0
            else:
                skip, marker = 0, False
            free = self.capacity - (write - self._get(_OFF_READ))
            if free >= skip + need:
                break
            if self.closed or (deadline is not None
                               and time.monotonic() >= deadline):
                return None
            time.sleep(POLL_S)
        if marker:
            RECORD_HEADER.pack_into(buf, HEADER_SIZE + pos, 0, WRAP, 0)
        start = (write + skip) % self.capacity
        RECORD_HEADER.pack_into(buf, HEADER_SIZE + start,
                                self._get(_OFF_ITEMS_COMMITTED), nbytes, 0)
        self._reserved_end = write + skip + need
        self._set(_OFF_WRITE, self._reserved_end)  # reserve BEFORE payload
        data0 = HEADER_SIZE + start + RECORD_HEADER.size
        return buf[data0:data0 + nbytes]

    def commit(self) -> None:
        """Publish the record reserved by the last :meth:`reserve`."""
        if _fault is not None:
            # firing here (InjectedTorn) leaves the reservation
            # uncommitted — exactly the torn write recover() discards
            _fault("ring.commit")
        self._set(_OFF_ITEMS_COMMITTED,
                  self._get(_OFF_ITEMS_COMMITTED) + 1)
        self._set(_OFF_COMMIT, self._reserved_end)

    def push(self, payload, timeout: Optional[float] = None) -> bool:
        """Reserve + copy + commit one record; False on timeout (full)."""
        data = memoryview(payload)
        view = self.reserve(len(data), timeout=timeout)
        if view is None:
            return False
        view[:] = data
        self.commit()
        return True

    # -- consumer -------------------------------------------------------------
    def pop(self, timeout: Optional[float] = None) -> Optional[bytes]:
        """Pop the oldest committed record (None on timeout). Only
        committed records are ever visible — a torn (reserved, never
        committed) tail is invisible by construction."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        buf = self._shm.buf
        while True:
            if self.closed:
                return None
            read = self._get(_OFF_READ)
            if read < self._get(_OFF_COMMIT):
                pos = read % self.capacity
                rem = self.capacity - pos
                if rem < RECORD_HEADER.size:       # implicit tail skip
                    self._set(_OFF_READ, read + rem)
                    continue
                seq, nbytes, _ = RECORD_HEADER.unpack_from(
                    buf, HEADER_SIZE + pos)
                if nbytes == WRAP:
                    self._set(_OFF_READ, read + rem)
                    continue
                # bound by what reserve() can legally have written AND by
                # the mapping — a corrupt length must raise, never yield
                # a silently clamped short read
                if (nbytes > self.max_record()
                        or pos + RECORD_HEADER.size + nbytes
                        > self.capacity):
                    raise RingError(f"corrupt ring record: {nbytes} bytes "
                                    f"claimed at offset {read}")
                expect = self._get(_OFF_ITEMS_READ)
                if seq != expect:
                    raise RingError(f"corrupt ring: record seq {seq} != "
                                    f"expected {expect}")
                data0 = HEADER_SIZE + pos + RECORD_HEADER.size
                out = bytes(buf[data0:data0 + nbytes])
                self._set(_OFF_ITEMS_READ, expect + 1)
                self._set(_OFF_READ,
                          read + RECORD_HEADER.size + _pad8(nbytes))
                return out
            if self.closed or (deadline is not None
                               and time.monotonic() >= deadline):
                return None
            time.sleep(POLL_S)

    # -- recovery -------------------------------------------------------------
    def recover(self) -> bool:
        """Discard an uncommitted (torn) reservation left by a producer
        that died mid-copy: reset ``write`` back to ``commit``. Call
        before producing into a ring taken over from a dead peer.
        Returns True iff a torn tail was discarded."""
        write, commit = self._get(_OFF_WRITE), self._get(_OFF_COMMIT)
        if write == commit:
            return False
        self._set(_OFF_WRITE, commit)
        self._set(_OFF_TORN, self._get(_OFF_TORN) + 1)
        return True

    # -- introspection --------------------------------------------------------
    def __len__(self) -> int:
        """Committed-but-unread records."""
        return int(self._get(_OFF_ITEMS_COMMITTED)
                   - self._get(_OFF_ITEMS_READ))

    def stats(self) -> Dict[str, float]:
        return {
            "capacity_bytes": float(self.capacity),
            "used_bytes": float(self._get(_OFF_COMMIT)
                                - self._get(_OFF_READ)),
            "items_pushed": float(self._get(_OFF_ITEMS_COMMITTED)),
            "items_popped": float(self._get(_OFF_ITEMS_READ)),
            "depth_items": float(len(self)),
            "torn_discards": float(self._get(_OFF_TORN)),
        }

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Unmap (both sides); a blocked push/pop returns within one poll
        slice. Unlinking is the creator's job (:meth:`unlink`)."""
        if self.closed:
            return
        self.closed = True
        # give any same-process waiter a chance to observe `closed` before
        # the mapping disappears under it
        time.sleep(POLL_S)
        try:
            self._shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        """Remove the segment name (idempotent; creator-owns-lifetime,
        but the server may sweep a dead creator's ring — both tolerate
        the other having gone first)."""
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass
