"""Persistent shared-memory ring buffer: the streaming data plane.

The per-message SHM path (:class:`~repro.runtime.transport.channel.ShmChannel`)
pays a ``shm_open`` + ``mmap`` + ``unlink`` syscall trio for every payload
and forces the server to keep an LRU of orphan segment names. A
:class:`ShmRing` replaces that churn with ONE segment per channel
direction, created at connect time and reused for every record — payloads
cross the boundary at memcpy speed and the only thing the server ever has
to sweep is the ring itself.

Layout (one 64-byte header cacheline, then ``capacity`` data bytes)::

    0   8s  magic "ACRLRNG1"
    8   u64 capacity                (data bytes; multiple of 16)
    16  u64 write   — RESERVE offset: monotone byte offset the producer
                      has claimed (advanced BEFORE the payload memcpy)
    24  u64 commit  — COMMIT offset: records below it are fully written;
                      the consumer never reads past it (torn-write guard)
    32  u64 read    — consumer offset (monotone)
    40  u64 items_committed
    48  u64 items_read
    56  u64 torn_discards          (recover() bumps it per discarded tail)

Records are ``[u64 seq | u32 nbytes | u32 flags | payload]`` padded to 8
bytes. A :meth:`reserve`-based record that would straddle the end of the
data area is preceded by a WRAP marker (``nbytes = 0xFFFFFFFF``) and
restarts at offset 0 (writers get one contiguous view); a :meth:`push`
record instead *splits* — header contiguous, payload tail wrapping to
offset 0, flagged ``FLAG_SPLIT`` — so the tail bytes are not wasted. A
tail shorter than a record header is skipped implicitly by both sides.
Offsets are monotone (never wrapped), so ``free = capacity - (write -
read)`` with no ambiguity between full and empty.

Consumers have two pop flavors. :meth:`pop` is the classic copying pop.
:meth:`pop_view` is the zero-copy ingest path: it returns a
:class:`RingView` over the committed region WITHOUT advancing the read
offset — the producer cannot reclaim the bytes under a live view (a full
ring simply refuses the push) until the consumer calls
:meth:`RingView.release`. Releases are ordered: the read offset advances
over the released *prefix* only, so out-of-order releases are safe.
Split records cannot be viewed contiguously and fall back to a two-piece
copy (``RingView.copied`` is True); the per-ring ``bytes_copied`` /
``views_served`` counters make the copy-elimination observable.

Torn-write protection is the two-offset header: the producer publishes
``write`` (reserve) before the memcpy and ``commit`` only after it, so a
producer dying mid-copy leaves ``write > commit`` — the consumer never
sees the partial record, and the next producer to take over the ring
calls :meth:`recover` to discard the uncommitted tail. Each record also
carries its sequence number (``items_committed`` at reserve time); a
mismatch against ``items_read`` on the consumer side means the ring was
corrupted and raises :class:`RingError` instead of yielding garbage.

Discipline: single producer, single consumer (one process each side) —
exactly the shape of one transport connection. Both sides may live in
the same process (tests, benchmarks).
"""
from __future__ import annotations

import os
import struct
import threading
import time
from typing import Dict, List, Optional, Tuple

try:
    from multiprocessing import shared_memory
except ImportError:  # pragma: no cover — stdlib on every target platform
    shared_memory = None

MAGIC = b"ACRLRNG1"
HEADER_SIZE = 64
RECORD_HEADER = struct.Struct("<QII")          # seq, nbytes, flags
WRAP = 0xFFFFFFFF                              # nbytes sentinel: skip to 0
FLAG_SPLIT = 0x1                               # payload wraps to offset 0

_U64 = struct.Struct("<Q")
_OFF_CAPACITY = 8
_OFF_WRITE = 16
_OFF_COMMIT = 24
_OFF_READ = 32
_OFF_ITEMS_COMMITTED = 40
_OFF_ITEMS_READ = 48
_OFF_TORN = 56

#: polling granularity of blocking push/pop waits — the ring is a hot
#: path, so the sleep is short; close()/deadlines bound every wait
POLL_S = 0.0005

__all__ = ["RingError", "RingView", "ShmRing", "MAGIC", "HEADER_SIZE",
           "WRAP", "FLAG_SPLIT"]


class RingError(RuntimeError):
    """Structural ring failure: bad magic, oversized record, corruption."""


# import-gated fault injection (see transport.faults): inert — not even
# imported — unless REPRO_FAULTS is set. The gate sits below RingError
# because faults.py imports it from this (then partially-initialized)
# module.
if os.environ.get("REPRO_FAULTS"):
    from repro.runtime.transport.faults import fault_point as _fault
else:
    _fault = None


def _pad8(n: int) -> int:
    return (n + 7) & ~7


class RingView:
    """A popped-but-not-yet-released record (zero-copy ingest lease).

    ``data`` is a read-only memoryview straight into the committed ring
    region (``copied`` False) or reassembled bytes when the record was
    wraparound-split (``copied`` True). The ring's read offset does NOT
    advance until :meth:`release` — while the lease is live the producer
    sees the bytes as occupied and a full ring refuses to overwrite them.
    Releases may arrive out of order; the ring advances over the released
    prefix only. Usable as a context manager; release is idempotent.
    """

    __slots__ = ("data", "seq", "nbytes", "copied", "_ring", "_end",
                 "_released")

    def __init__(self, ring: "ShmRing", data, seq: int, end: int, *,
                 copied: bool):
        self.data = data
        self.seq = seq
        self.nbytes = len(data)
        self.copied = copied
        self._ring = ring
        self._end = end
        self._released = False

    def release(self) -> None:
        """Return the leased region to the producer (idempotent)."""
        if self._released:
            return
        self._released = True
        if not self.copied:
            data, self.data = self.data, bytes()
            try:
                data.release()               # drop the SHM buffer pin
            except BufferError:
                # numpy views decoded over the lease still export the
                # buffer; by the lease contract their CONTENTS are dead
                # now (the consumer copied what it needed) — the mapping
                # pin itself dies with the arrays via refcounting
                pass
            except AttributeError:  # pragma: no cover - bytes fallback
                pass
        self._ring._advance_released()

    def __len__(self) -> int:
        return self.nbytes

    def __enter__(self) -> "RingView":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShmRing:
    """Single-producer single-consumer byte ring over one SHM segment."""

    def __init__(self, shm: "shared_memory.SharedMemory", *, created: bool):
        self._shm = shm
        self.created = created
        self.closed = False
        buf = shm.buf
        if bytes(buf[:8]) != MAGIC:
            raise RingError(f"bad ring magic in segment {shm.name!r}")
        self.capacity = _U64.unpack_from(buf, _OFF_CAPACITY)[0]
        if HEADER_SIZE + self.capacity > len(buf):
            raise RingError(f"ring segment {shm.name!r} truncated")
        # consumer-side zero-copy state (per attachment, not in the SHM
        # header: leases are a property of THIS consumer's mapping)
        self._view_lock = threading.Lock()
        self._pending_views: List[RingView] = []
        self.views_served = 0        # zero-copy pops (no payload memcpy)
        self.bytes_copied = 0        # payload bytes memcpy'd on the pop path
        self.split_fallbacks = 0     # pop_view forced to copy (FLAG_SPLIT)

    # -- construction ---------------------------------------------------------
    @classmethod
    def create(cls, capacity: int, name: Optional[str] = None) -> "ShmRing":
        """Create a fresh ring with at least ``capacity`` data bytes."""
        if shared_memory is None:
            raise RingError("shared memory unavailable on this platform")
        capacity = max(_pad8(capacity), 4 * RECORD_HEADER.size)
        capacity = (capacity + 15) & ~15               # multiple of 16
        if name is None:
            # default to the sweepable acrl<pid>x… scheme so a later
            # server incarnation can reclaim rings a SIGKILL leaked
            from repro.runtime.transport.resilience import shm_name
            while True:
                try:
                    shm = shared_memory.SharedMemory(
                        create=True, size=HEADER_SIZE + capacity,
                        name=shm_name())
                    break
                except FileExistsError:    # 32-bit token collision
                    continue
        else:
            shm = shared_memory.SharedMemory(
                create=True, size=HEADER_SIZE + capacity, name=name)
        shm.buf[:HEADER_SIZE] = bytes(HEADER_SIZE)     # zero all offsets
        shm.buf[:8] = MAGIC
        _U64.pack_into(shm.buf, _OFF_CAPACITY, capacity)
        return cls(shm, created=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRing":
        """Attach to a ring created by the peer (no unlink duty)."""
        if shared_memory is None:
            raise RingError("shared memory unavailable on this platform")
        return cls(shared_memory.SharedMemory(name=name), created=False)

    @property
    def name(self) -> str:
        return self._shm.name

    # -- header accessors (each field is ONE aligned u64 write: no tearing
    # across fields, and an 8-byte aligned store is atomic on every target)
    def _get(self, off: int) -> int:
        return _U64.unpack_from(self._shm.buf, off)[0]

    def _set(self, off: int, value: int) -> None:
        _U64.pack_into(self._shm.buf, off, value)

    # -- producer -------------------------------------------------------------
    def max_record(self) -> int:
        """Largest payload a push can ever carry (sized so one record plus
        its worst-case wrap skip always fits an empty ring)."""
        return self.capacity // 2 - RECORD_HEADER.size

    def reserve(self, nbytes: int,
                timeout: Optional[float] = None) -> Optional[memoryview]:
        """Claim space for one ``nbytes`` record; returns a writable view
        of the payload area (None on timeout). The reservation is
        published BEFORE the caller copies — :meth:`commit` makes it
        visible to the consumer; an uncommitted reservation is what
        :meth:`recover` discards."""
        if self.closed:
            return None
        if nbytes > self.max_record():
            raise RingError(f"record of {nbytes} bytes exceeds ring "
                            f"max {self.max_record()} (capacity "
                            f"{self.capacity})")
        need = RECORD_HEADER.size + _pad8(nbytes)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        buf = self._shm.buf
        while True:
            if self.closed:
                return None
            write = self._get(_OFF_WRITE)
            pos = write % self.capacity
            rem = self.capacity - pos
            if rem < RECORD_HEADER.size:
                skip, marker = rem, False          # implicit tail skip
            elif rem < need:
                skip, marker = rem, True           # WRAP marker, restart at 0
            else:
                skip, marker = 0, False
            free = self.capacity - (write - self._get(_OFF_READ))
            if free >= skip + need:
                break
            if self.closed or (deadline is not None
                               and time.monotonic() >= deadline):
                return None
            time.sleep(POLL_S)
        if marker:
            RECORD_HEADER.pack_into(buf, HEADER_SIZE + pos, 0, WRAP, 0)
        start = (write + skip) % self.capacity
        RECORD_HEADER.pack_into(buf, HEADER_SIZE + start,
                                self._get(_OFF_ITEMS_COMMITTED), nbytes, 0)
        self._reserved_end = write + skip + need
        self._set(_OFF_WRITE, self._reserved_end)  # reserve BEFORE payload
        data0 = HEADER_SIZE + start + RECORD_HEADER.size
        return buf[data0:data0 + nbytes]

    def commit(self) -> None:
        """Publish the record reserved by the last :meth:`reserve`."""
        if _fault is not None:
            # firing here (InjectedTorn) leaves the reservation
            # uncommitted — exactly the torn write recover() discards
            _fault("ring.commit")
        self._set(_OFF_ITEMS_COMMITTED,
                  self._get(_OFF_ITEMS_COMMITTED) + 1)
        self._set(_OFF_COMMIT, self._reserved_end)

    def push(self, payload, timeout: Optional[float] = None) -> bool:
        """Copy + commit one record; False on timeout (full).

        Unlike :meth:`reserve` (which must hand back ONE contiguous
        writable view and therefore wastes the tail behind a WRAP
        marker), push owns the memcpy and can *split* a record that
        would straddle the end of the data area: header contiguous at
        the tail, payload remainder wrapping to offset 0, flagged
        ``FLAG_SPLIT``. Consumers reassemble split records by copy —
        :meth:`pop_view` falls back to a two-piece copy for them.
        """
        data = memoryview(payload)
        nbytes = len(data)
        if nbytes > self.max_record():
            raise RingError(f"record of {nbytes} bytes exceeds ring "
                            f"max {self.max_record()} (capacity "
                            f"{self.capacity})")
        need = RECORD_HEADER.size + _pad8(nbytes)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        buf = self._shm.buf
        while True:
            if self.closed:
                return False
            write = self._get(_OFF_WRITE)
            pos = write % self.capacity
            rem = self.capacity - pos
            if rem < RECORD_HEADER.size:
                skip, split = rem, False           # implicit tail skip
            elif rem < need:
                skip, split = 0, True              # wraparound-split record
            else:
                skip, split = 0, False
            free = self.capacity - (write - self._get(_OFF_READ))
            if free >= skip + need:
                break
            if self.closed or (deadline is not None
                               and time.monotonic() >= deadline):
                return False                       # full — e.g. live views
            time.sleep(POLL_S)
        start = (write + skip) % self.capacity
        RECORD_HEADER.pack_into(buf, HEADER_SIZE + start,
                                self._get(_OFF_ITEMS_COMMITTED), nbytes,
                                FLAG_SPLIT if split else 0)
        self._reserved_end = write + skip + need
        self._set(_OFF_WRITE, self._reserved_end)  # reserve BEFORE payload
        data0 = HEADER_SIZE + start + RECORD_HEADER.size
        if split:
            head = (self.capacity - start) - RECORD_HEADER.size
            buf[data0:data0 + head] = data[:head]
            buf[HEADER_SIZE:HEADER_SIZE + nbytes - head] = data[head:]
        else:
            buf[data0:data0 + nbytes] = data
        self.commit()
        return True

    # -- consumer -------------------------------------------------------------
    def _skip(self, read: int, by: int) -> None:
        """Advance the consumer cursor over a WRAP marker / implicit tail.
        With live views pending, the read offset must not move (the
        producer would reclaim leased bytes) — fold the skip into the
        newest lease's extent so its release covers it."""
        with self._view_lock:
            if self._pending_views:
                self._pending_views[-1]._end = read + by
            else:
                self._set(_OFF_READ, read + by)

    def _cursor(self) -> int:
        """Next unconsumed offset: past the newest lease when any are
        live, the shared read offset otherwise."""
        with self._view_lock:
            if self._pending_views:
                return self._pending_views[-1]._end
        return self._get(_OFF_READ)

    def _advance_released(self) -> None:
        """Publish the released prefix of the lease queue: the shared
        read offset (and items_read) jump over every leading lease whose
        consumer is done with it."""
        with self._view_lock:
            while self._pending_views and self._pending_views[0]._released:
                view = self._pending_views.pop(0)
                self._set(_OFF_ITEMS_READ,
                          self._get(_OFF_ITEMS_READ) + 1)
                self._set(_OFF_READ, view._end)

    def _pop_record(self,
                    timeout: Optional[float] = None) -> Optional[RingView]:
        """Shared pop core: locate + lease the oldest committed record.
        Contiguous records come back as an unreleased zero-copy lease;
        wraparound-split records are reassembled by copy and their lease
        auto-released (the ordered prefix rule still holds). Only
        committed records are ever visible — a torn (reserved, never
        committed) tail is invisible by construction."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        buf = self._shm.buf
        while True:
            if self.closed:
                return None
            read = self._cursor()
            if read < self._get(_OFF_COMMIT):
                pos = read % self.capacity
                rem = self.capacity - pos
                if rem < RECORD_HEADER.size:       # implicit tail skip
                    self._skip(read, rem)
                    continue
                seq, nbytes, flags = RECORD_HEADER.unpack_from(
                    buf, HEADER_SIZE + pos)
                if nbytes == WRAP:
                    self._skip(read, rem)
                    continue
                # bound by what a producer can legally have written AND
                # by the mapping — a corrupt length must raise, never
                # yield a silently clamped short read
                if (nbytes > self.max_record()
                        or (not flags & FLAG_SPLIT
                            and pos + RECORD_HEADER.size + nbytes
                            > self.capacity)):
                    raise RingError(f"corrupt ring record: {nbytes} bytes "
                                    f"claimed at offset {read}")
                with self._view_lock:
                    expect = (self._get(_OFF_ITEMS_READ)
                              + len(self._pending_views))
                if seq != expect:
                    raise RingError(f"corrupt ring: record seq {seq} != "
                                    f"expected {expect}")
                end = read + RECORD_HEADER.size + _pad8(nbytes)
                data0 = HEADER_SIZE + pos + RECORD_HEADER.size
                if flags & FLAG_SPLIT:
                    head = rem - RECORD_HEADER.size
                    data = (bytes(buf[data0:data0 + head])
                            + bytes(buf[HEADER_SIZE:
                                        HEADER_SIZE + nbytes - head]))
                    self.bytes_copied += nbytes
                    self.split_fallbacks += 1
                    view = RingView(self, data, seq, end, copied=True)
                else:
                    mv = buf[data0:data0 + nbytes].toreadonly()
                    view = RingView(self, mv, seq, end, copied=False)
                with self._view_lock:
                    self._pending_views.append(view)
                if view.copied:
                    # nothing pins the ring for a copied record; ordered
                    # advance still waits for earlier live leases
                    view.release()
                return view
            if self.closed or (deadline is not None
                               and time.monotonic() >= deadline):
                return None
            time.sleep(POLL_S)

    def pop(self, timeout: Optional[float] = None) -> Optional[bytes]:
        """Pop the oldest committed record as owned bytes (None on
        timeout) — the classic copying pop."""
        view = self._pop_record(timeout=timeout)
        if view is None:
            return None
        if view.copied:
            return view.data                       # already owned bytes
        out = bytes(view.data)
        self.bytes_copied += len(out)
        view.release()
        return out

    def pop_view(self, timeout: Optional[float] = None) -> Optional[RingView]:
        """Zero-copy pop: lease the oldest committed record in place (see
        :class:`RingView`). The caller MUST release the view — the
        producer blocks on the leased bytes until then. Split records
        fall back to an (auto-released) copy."""
        view = self._pop_record(timeout=timeout)
        if view is not None and not view.copied:
            self.views_served += 1
        return view

    # -- recovery -------------------------------------------------------------
    def recover(self) -> bool:
        """Discard an uncommitted (torn) reservation left by a producer
        that died mid-copy: reset ``write`` back to ``commit``. Call
        before producing into a ring taken over from a dead peer.
        Returns True iff a torn tail was discarded."""
        write, commit = self._get(_OFF_WRITE), self._get(_OFF_COMMIT)
        if write == commit:
            return False
        self._set(_OFF_WRITE, commit)
        self._set(_OFF_TORN, self._get(_OFF_TORN) + 1)
        return True

    # -- broadcast lane (single writer, many positional readers) --------------
    def publish_blob(self, payload) -> Tuple[int, int]:
        """Broadcast-lane write: one record per published version, located
        by absolute position instead of popped. Readers never advance the
        ring's read offset, so the writer reclaims EVERYTHING unread
        before each write (a reader mid-copy of an old version detects
        the overwrite via :meth:`read_at`'s header re-check and falls
        back). Returns ``(header_pos, seq)`` for the acquire reply."""
        data = memoryview(payload)
        nbytes = len(data)
        self._set(_OFF_ITEMS_READ, self._get(_OFF_ITEMS_COMMITTED))
        self._set(_OFF_READ, self._get(_OFF_COMMIT))
        seq = self._get(_OFF_ITEMS_COMMITTED)
        view = self.reserve(nbytes, timeout=0)
        if view is None:  # reclaim guarantees room up to max_record
            raise RingError(f"weight-lane reserve of {nbytes} bytes "
                            f"failed (max {self.max_record()})")
        need = RECORD_HEADER.size + _pad8(nbytes)
        pos = (self._reserved_end - need) % self.capacity
        view[:] = data
        try:
            view.release()
        except AttributeError:  # pragma: no cover
            pass
        self.commit()
        return pos, seq

    def read_at(self, pos: int, seq: int, nbytes: int) -> Optional[bytes]:
        """Positional broadcast-lane read with torn-read detection: the
        record header at ``pos`` is validated before AND after the copy.
        The writer reclaiming the lane for a newer version mid-copy
        changes the header (seqs are monotone, never reused), so a torn
        copy comes back as None and the caller falls back to the socket
        body."""
        hdr = RECORD_HEADER.size
        if pos < 0 or pos + hdr + nbytes > self.capacity:
            return None
        buf = self._shm.buf
        rseq, rnbytes, _ = RECORD_HEADER.unpack_from(buf, HEADER_SIZE + pos)
        if rseq != seq or rnbytes != nbytes:
            return None
        out = bytes(buf[HEADER_SIZE + pos + hdr:
                        HEADER_SIZE + pos + hdr + nbytes])
        rseq, rnbytes, _ = RECORD_HEADER.unpack_from(buf, HEADER_SIZE + pos)
        if rseq != seq or rnbytes != nbytes:
            return None
        return out

    # -- introspection --------------------------------------------------------
    def __len__(self) -> int:
        """Committed-but-unread records."""
        return int(self._get(_OFF_ITEMS_COMMITTED)
                   - self._get(_OFF_ITEMS_READ))

    def stats(self) -> Dict[str, float]:
        return {
            "capacity_bytes": float(self.capacity),
            "used_bytes": float(self._get(_OFF_COMMIT)
                                - self._get(_OFF_READ)),
            "items_pushed": float(self._get(_OFF_ITEMS_COMMITTED)),
            "items_popped": float(self._get(_OFF_ITEMS_READ)),
            "depth_items": float(len(self)),
            "torn_discards": float(self._get(_OFF_TORN)),
            "views_served": float(self.views_served),
            "bytes_copied": float(self.bytes_copied),
            "split_fallbacks": float(self.split_fallbacks),
            "views_live": float(len(self._pending_views)),
        }

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        """Unmap (both sides); a blocked push/pop returns within one poll
        slice. Unlinking is the creator's job (:meth:`unlink`)."""
        if self.closed:
            return
        self.closed = True
        # give any same-process waiter a chance to observe `closed` before
        # the mapping disappears under it
        time.sleep(POLL_S)
        for view in list(self._pending_views):
            view.release()       # drop SHM pins so the unmap can proceed
        try:
            self._shm.close()
        except (OSError, BufferError):
            pass

    def unlink(self) -> None:
        """Remove the segment name (idempotent; creator-owns-lifetime,
        but the server may sweep a dead creator's ring — both tolerate
        the other having gone first)."""
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass
