"""Client-side transport channels: the ExperienceChannel contract over a
process boundary.

A :class:`SocketChannel` is a proxy for a channel hosted by a
:class:`~repro.runtime.transport.server.TransportServer` in another
process. It implements the same ``put`` / ``pop_batch`` surface as
:class:`~repro.runtime.experience.FifoChannel`, with the same backpressure
semantics — the *server-side* channel's policy decides, and the boolean
verdict (accepted / dropped / block-timed-out) crosses the wire:

  * ``put`` returns False iff the remote channel rejected the item;
  * ``pop_batch(n, timeout)`` blocks up to ``timeout`` (None = forever),
    long-polling the server in short slices so a concurrent ``close()``
    always unblocks it promptly (it returns None, like a timeout);
  * after ``close()``, ``put`` returns False and ``pop_batch`` returns
    None — shutdown is a data-plane no-op, not an exception storm.

:class:`ShmChannel` speaks the identical protocol but moves large payloads
out-of-band through POSIX shared memory: the socket carries only the
segment name, the bytes never transit the TCP stack. Ownership rule:
whoever *creates* a segment unlinks it, after the consuming side has
acknowledged (the reply for requests; the next frame on the same
connection for responses).
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.runtime.experience import ExperienceChannel
from repro.runtime.transport.codec import (decode_pytree, encode_pytree,
                                           recv_frame, send_frame)

try:
    from multiprocessing import shared_memory
except ImportError:  # pragma: no cover — stdlib on every target platform
    shared_memory = None

POLL_S = 0.5          # per-RPC slice of a long pop/acquire wait

__all__ = ["TransportError", "ChannelClosed", "WireClient", "long_poll",
           "SocketChannel", "ShmChannel", "shm_read", "shm_write"]


class TransportError(RuntimeError):
    """A wire-level failure (server error, protocol violation)."""


class ChannelClosed(TransportError):
    """The connection is gone — closed locally or by the peer."""


def shm_write(data: bytes) -> "shared_memory.SharedMemory":
    """Create a shared-memory segment holding ``data`` (caller unlinks)."""
    if shared_memory is None:
        raise TransportError("shared memory unavailable on this platform")
    shm = shared_memory.SharedMemory(create=True, size=max(len(data), 1))
    shm.buf[:len(data)] = data
    return shm


def shm_read(name: str, size: int) -> bytes:
    """Copy ``size`` bytes out of segment ``name`` (no unlink — the
    creator owns the lifetime).

    No resource-tracker compensation is needed even though attaching
    registers the name on CPython < 3.13: spawned workers INHERIT the
    parent's tracker process, so the attach registration collapses into
    the creator's (the tracker cache is a set) and the creator's unlink
    removes the single entry. A worker killed while holding segments
    leaves them to that same tracker's exit cleanup — which is the
    tracker working as intended, not a leak."""
    if shared_memory is None:
        raise TransportError("shared memory unavailable on this platform")
    shm = shared_memory.SharedMemory(name=name)
    try:
        return bytes(shm.buf[:size])
    finally:
        shm.close()


class WireClient:
    """One blocking request/response connection with a call lock.

    Each proxy object owns one connection; concurrent callers serialize on
    the lock (requests are short except deliberately-bounded long-polls).
    ``close()`` from any thread shuts the socket down, which unblocks a
    caller parked in ``recv`` with :class:`ChannelClosed`.
    """

    def __init__(self, address: Tuple[str, int], *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16):
        deadline = time.monotonic() + connect_timeout
        last: Optional[Exception] = None
        while True:
            try:
                self._sock = socket.create_connection(
                    address, timeout=connect_timeout)
                break
            except OSError as e:       # server may still be binding
                last = e
                if time.monotonic() >= deadline:
                    raise TransportError(
                        f"cannot connect to transport server at "
                        f"{address}: {e}") from last
                time.sleep(0.05)
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._lock = threading.Lock()
        self._shm_threshold = shm_threshold
        self.closed = False

    def request(self, header: Dict, body: bytes = b"", *,
                oob: bool = False) -> Tuple[Dict, bytes]:
        """One round-trip. ``oob=True`` routes a large body through shared
        memory instead of the socket (the SHM data plane)."""
        shm = None
        if (oob and shared_memory is not None
                and len(body) >= self._shm_threshold):
            shm = shm_write(body)
            header = {**header, "shm": shm.name, "shm_size": len(body)}
            body = b""
        try:
            with self._lock:
                if self.closed:
                    raise ChannelClosed("transport client is closed")
                try:
                    send_frame(self._sock, header, body)
                    resp = recv_frame(self._sock)
                except (OSError, ValueError) as e:
                    self.close()
                    raise ChannelClosed(f"transport connection lost: {e}") \
                        from e
            if resp is None:
                self.close()
                raise ChannelClosed("server closed the connection")
            rh, rbody = resp
            if rh.get("err"):
                raise TransportError(rh["err"])
            if rh.get("shm"):          # out-of-band response body
                rbody = shm_read(rh["shm"], rh["shm_size"])
            return rh, rbody
        finally:
            if shm is not None:
                shm.close()
                try:                   # server consumed it during the RTT
                    shm.unlink()
                except FileNotFoundError:
                    pass

    def close(self) -> None:
        self.closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


def long_poll(client: WireClient, make_header,
              timeout: Optional[float]) -> Optional[Tuple[Dict, bytes]]:
    """Blocking-request idiom shared by pop_batch and acquire: re-issue
    the request in bounded ``POLL_S`` slices until the server answers
    ``ok``, the deadline passes, or the client closes (→ None, so a
    concurrent ``close()`` always unblocks the caller within one slice).
    ``make_header(slice_timeout)`` builds each request; a ``timeout`` of 0
    still makes exactly one non-blocking attempt."""
    deadline = None if timeout is None else time.monotonic() + timeout
    first = True
    while not client.closed:
        remaining = (None if deadline is None
                     else deadline - time.monotonic())
        if not first and remaining is not None and remaining <= 0:
            return None
        t = (POLL_S if remaining is None
             else max(min(POLL_S, remaining), 0.0))
        first = False
        try:
            resp, body = client.request(make_header(t))
        except ChannelClosed:
            return None
        if resp.get("ok"):
            return resp, body
    return None


class SocketChannel(ExperienceChannel):
    """Remote ExperienceChannel proxy: TCP data plane."""

    #: whether payload bodies travel out-of-band (overridden by ShmChannel)
    oob = False

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16):
        self.name = name
        self.address = tuple(address)
        self._client = WireClient(address, connect_timeout=connect_timeout,
                                  shm_threshold=shm_threshold)

    # -- ExperienceChannel surface -------------------------------------------
    def put(self, item: Any) -> bool:
        try:
            resp, _ = self._client.request(
                {"m": "chan.put", "chan": self.name},
                encode_pytree(item), oob=self.oob)
        except ChannelClosed:
            return False
        return bool(resp.get("ok"))

    def pop_batch(self, n: int, timeout: Optional[float] = None
                  ) -> Optional[List[Any]]:
        got = long_poll(
            self._client,
            lambda t: {"m": "chan.pop", "chan": self.name, "n": n,
                       "timeout": t, "want_shm": self.oob},
            timeout)
        return None if got is None else decode_pytree(got[1])

    def __len__(self) -> int:
        try:
            resp, _ = self._client.request({"m": "chan.len",
                                            "chan": self.name})
        except ChannelClosed:
            return 0
        return int(resp["len"])

    def stats(self) -> Dict[str, float]:
        try:
            resp, _ = self._client.request({"m": "chan.stats",
                                            "chan": self.name})
        except ChannelClosed:
            return {"depth": 0.0}
        return {k: float(v) for k, v in resp["stats"].items()}

    # -- lifecycle ------------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._client.closed

    def close(self) -> None:
        """Tear the connection down; a blocked ``pop_batch`` returns None
        within one poll slice, subsequent ``put``s return False."""
        self._client.close()


class ShmChannel(SocketChannel):
    """SocketChannel with a shared-memory data plane for large payloads.

    The control messages (verdicts, lengths, small items under the
    threshold) still ride the socket; anything bigger moves through a
    per-message SHM segment, so segment batches and weight payloads cross
    the boundary at memcpy speed.
    """

    oob = True

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16):
        if shared_memory is None:
            raise TransportError(
                "ShmChannel needs multiprocessing.shared_memory")
        super().__init__(address, name, connect_timeout=connect_timeout,
                         shm_threshold=shm_threshold)
