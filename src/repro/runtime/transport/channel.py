"""Client-side transport channels: the ExperienceChannel contract over a
process boundary.

A :class:`SocketChannel` is a proxy for a channel hosted by a
:class:`~repro.runtime.transport.server.TransportServer` in another
process. It implements the same ``put`` / ``pop_batch`` surface as
:class:`~repro.runtime.experience.FifoChannel`, with the same backpressure
semantics — the *server-side* channel's policy decides, and the boolean
verdict (accepted / dropped / block-timed-out) crosses the wire:

  * ``put`` returns False iff the remote channel rejected the item;
  * ``pop_batch(n, timeout)`` blocks up to ``timeout`` (None = forever),
    long-polling the server in short slices so a concurrent ``close()``
    always unblocks it promptly (it returns None, like a timeout);
  * after ``close()``, ``put`` returns False and ``pop_batch`` returns
    None — shutdown is a data-plane no-op, not an exception storm.

:class:`ShmChannel` speaks the identical protocol but moves large payloads
out-of-band through POSIX shared memory: the socket carries only the
segment name, the bytes never transit the TCP stack. Ownership rule:
whoever *creates* a segment unlinks it, after the consuming side has
acknowledged (the reply for requests; the next frame on the same
connection for responses).
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.runtime.experience import ExperienceChannel
from repro.runtime.transport.codec import (decode_pytree, encode_pytree,
                                           recv_frame, send_frame)

try:
    from multiprocessing import shared_memory
except ImportError:  # pragma: no cover — stdlib on every target platform
    shared_memory = None

POLL_S = 0.5          # per-RPC slice of a long pop/acquire wait

__all__ = ["TransportError", "ChannelClosed", "WireClient", "long_poll",
           "SocketChannel", "ShmChannel", "shm_read", "shm_write",
           "parse_address"]


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``; a bare ``":port"``/``"port"``
    falls back to loopback. The one parser every CLI/config shares."""
    host, _, port = address.rpartition(":")
    return (host or "127.0.0.1", int(port))


class TransportError(RuntimeError):
    """A wire-level failure (server error, protocol violation)."""


class ChannelClosed(TransportError):
    """The connection is gone — closed locally or by the peer."""


def shm_write(data: bytes) -> "shared_memory.SharedMemory":
    """Create a shared-memory segment holding ``data`` (caller unlinks)."""
    if shared_memory is None:
        raise TransportError("shared memory unavailable on this platform")
    shm = shared_memory.SharedMemory(create=True, size=max(len(data), 1))
    shm.buf[:len(data)] = data
    return shm


def shm_read(name: str, size: int) -> bytes:
    """Copy ``size`` bytes out of segment ``name`` (no unlink — the
    creator owns the lifetime).

    No resource-tracker compensation is needed even though attaching
    registers the name on CPython < 3.13: spawned workers INHERIT the
    parent's tracker process, so the attach registration collapses into
    the creator's (the tracker cache is a set) and the creator's unlink
    removes the single entry. A worker killed while holding segments
    leaves them to that same tracker's exit cleanup — which is the
    tracker working as intended, not a leak."""
    if shared_memory is None:
        raise TransportError("shared memory unavailable on this platform")
    shm = shared_memory.SharedMemory(name=name)
    try:
        return bytes(shm.buf[:size])
    finally:
        shm.close()


class WireClient:
    """One blocking request/response connection with a call lock.

    Each proxy object owns one connection; concurrent callers serialize on
    the lock (requests are short except deliberately-bounded long-polls).
    ``close()`` from any thread shuts the socket down, which unblocks a
    caller parked in ``recv`` with :class:`ChannelClosed`.

    With ``reconnect_attempts > 0`` the client survives a *server-side*
    connection drop: a failed round-trip redials with exponential backoff
    and re-issues the request up to that many times before surfacing
    :class:`ChannelClosed`. Retried requests are at-least-once — most
    server endpoints are either idempotent (``worker.report``,
    ``store.publish`` by version, ``store.state``) or tolerant of a
    duplicate (``chan.put``/``put_many``: a re-accepted segment is
    ordinary replay data). The exception is ``chan.pop``: if the reply is
    lost AFTER the server popped, the retry pops a fresh batch and the
    first one is gone — equivalent to a channel drop, acceptable for
    experience data (and remote pops are off the training hot path:
    remote workers produce, the trainer pops locally). ``on_reconnect``
    fires after each successful redial, under the call lock — proxies use
    it to bust version caches so state (e.g. the newest weight version)
    is re-acquired on the fresh connection.
    """

    def __init__(self, address: Tuple[str, int], *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 reconnect_backoff_max_s: float = 2.0,
                 on_reconnect=None):
        self.address = tuple(address)
        self._connect_timeout = connect_timeout
        self._lock = threading.Lock()
        self._shm_threshold = shm_threshold
        self._reconnect_attempts = reconnect_attempts
        self._reconnect_backoff_s = reconnect_backoff_s
        self._reconnect_backoff_max_s = reconnect_backoff_max_s
        self._on_reconnect = on_reconnect
        self.reconnects = 0
        self.closed = False
        self._sock = self._dial(connect_timeout)

    def _dial(self, timeout: float) -> socket.socket:
        deadline = time.monotonic() + timeout
        while True:
            try:
                sock = socket.create_connection(self.address,
                                                timeout=max(timeout, 0.05))
                break
            except OSError as e:       # server may still be binding
                if time.monotonic() >= deadline:
                    raise TransportError(
                        f"cannot connect to transport server at "
                        f"{self.address}: {e}") from e
                time.sleep(0.05)
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _redial(self, attempt: int) -> bool:
        """One backoff-then-reconnect try (caller holds the lock)."""
        delay = min(self._reconnect_backoff_s * (2 ** (attempt - 1)),
                    self._reconnect_backoff_max_s)
        time.sleep(delay)
        if self.closed:
            return False
        try:
            sock = self._dial(self._connect_timeout)
        except TransportError:
            return False
        try:
            self._sock.close()
        except OSError:
            pass
        self._sock = sock
        self.reconnects += 1
        if self._on_reconnect is not None:
            try:
                self._on_reconnect()
            except Exception:          # noqa: BLE001 — a cache-bust hook
                pass                   # must never poison the data path
        return True

    def request(self, header: Dict, body: bytes = b"", *,
                oob: bool = False) -> Tuple[Dict, bytes]:
        """One round-trip. ``oob=True`` routes a large body through shared
        memory instead of the socket (the SHM data plane)."""
        shm = None
        if (oob and shared_memory is not None
                and len(body) >= self._shm_threshold):
            shm = shm_write(body)
            header = {**header, "shm": shm.name, "shm_size": len(body)}
            body = b""
        try:
            with self._lock:
                if self.closed:
                    raise ChannelClosed("transport client is closed")
                resp = None
                last: Optional[Exception] = None
                for attempt in range(self._reconnect_attempts + 1):
                    if attempt and (self.closed or not self._redial(attempt)):
                        break
                    try:
                        send_frame(self._sock, header, body)
                        resp = recv_frame(self._sock)
                        if resp is None:   # clean EOF: peer closed on us
                            raise ConnectionError(
                                "server closed the connection")
                        break
                    except (OSError, ValueError) as e:
                        last = e
                        resp = None
                if resp is None:
                    self.close()
                    raise ChannelClosed(
                        f"transport connection lost: {last}") from last
            rh, rbody = resp
            if rh.get("err"):
                raise TransportError(rh["err"])
            if rh.get("shm"):          # out-of-band response body
                rbody = shm_read(rh["shm"], rh["shm_size"])
            return rh, rbody
        finally:
            if shm is not None:
                shm.close()
                try:                   # server consumed it during the RTT
                    shm.unlink()
                except FileNotFoundError:
                    pass

    def close(self) -> None:
        self.closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


def long_poll(client: WireClient, make_header,
              timeout: Optional[float]) -> Optional[Tuple[Dict, bytes]]:
    """Blocking-request idiom shared by pop_batch and acquire: re-issue
    the request in bounded ``POLL_S`` slices until the server answers
    ``ok``, the deadline passes, or the client closes (→ None, so a
    concurrent ``close()`` always unblocks the caller within one slice).
    ``make_header(slice_timeout)`` builds each request; a ``timeout`` of 0
    still makes exactly one non-blocking attempt."""
    deadline = None if timeout is None else time.monotonic() + timeout
    first = True
    while not client.closed:
        remaining = (None if deadline is None
                     else deadline - time.monotonic())
        if not first and remaining is not None and remaining <= 0:
            return None
        t = (POLL_S if remaining is None
             else max(min(POLL_S, remaining), 0.0))
        first = False
        try:
            resp, body = client.request(make_header(t))
        except ChannelClosed:
            return None
        if resp.get("ok"):
            return resp, body
    return None


class SocketChannel(ExperienceChannel):
    """Remote ExperienceChannel proxy: TCP data plane."""

    #: whether payload bodies travel out-of-band (overridden by ShmChannel)
    oob = False

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1):
        self.name = name
        self.address = tuple(address)
        self._client = WireClient(address, connect_timeout=connect_timeout,
                                  shm_threshold=shm_threshold,
                                  reconnect_attempts=reconnect_attempts,
                                  reconnect_backoff_s=reconnect_backoff_s)

    # -- ExperienceChannel surface -------------------------------------------
    def put(self, item: Any) -> bool:
        try:
            resp, _ = self._client.request(
                {"m": "chan.put", "chan": self.name},
                encode_pytree(item), oob=self.oob)
        except ChannelClosed:
            return False
        return bool(resp.get("ok"))

    def put_many(self, items: List[Any]) -> List[bool]:
        """Batched put: ONE codec blob + one round-trip for the whole
        flush; the server answers a per-item verdict vector from the
        hosted channel's own backpressure policy."""
        items = list(items)
        if not items:
            return []
        try:
            resp, _ = self._client.request(
                {"m": "chan.put_many", "chan": self.name,
                 "count": len(items)},
                encode_pytree(items), oob=self.oob)
        except ChannelClosed:
            return [False] * len(items)
        verdicts = [bool(v) for v in resp.get("verdicts", ())]
        # a malformed reply must not fabricate acceptance
        verdicts += [False] * (len(items) - len(verdicts))
        return verdicts[:len(items)]

    def pop_batch(self, n: int, timeout: Optional[float] = None
                  ) -> Optional[List[Any]]:
        got = long_poll(
            self._client,
            lambda t: {"m": "chan.pop", "chan": self.name, "n": n,
                       "timeout": t, "want_shm": self.oob},
            timeout)
        return None if got is None else decode_pytree(got[1])

    def __len__(self) -> int:
        try:
            resp, _ = self._client.request({"m": "chan.len",
                                            "chan": self.name})
        except ChannelClosed:
            return 0
        return int(resp["len"])

    def stats(self) -> Dict[str, float]:
        try:
            resp, _ = self._client.request({"m": "chan.stats",
                                            "chan": self.name})
        except ChannelClosed:
            return {"depth": 0.0}
        return {k: float(v) for k, v in resp["stats"].items()}

    # -- lifecycle ------------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._client.closed

    def close(self) -> None:
        """Tear the connection down; a blocked ``pop_batch`` returns None
        within one poll slice, subsequent ``put``s return False."""
        self._client.close()


class ShmChannel(SocketChannel):
    """SocketChannel with a shared-memory data plane for large payloads.

    The control messages (verdicts, lengths, small items under the
    threshold) still ride the socket; anything bigger moves through a
    per-message SHM segment, so segment batches and weight payloads cross
    the boundary at memcpy speed.
    """

    oob = True

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1):
        if shared_memory is None:
            raise TransportError(
                "ShmChannel needs multiprocessing.shared_memory")
        super().__init__(address, name, connect_timeout=connect_timeout,
                         shm_threshold=shm_threshold,
                         reconnect_attempts=reconnect_attempts,
                         reconnect_backoff_s=reconnect_backoff_s)
