"""Client-side transport channels: the ExperienceChannel contract over a
process boundary.

A :class:`SocketChannel` is a proxy for a channel hosted by a
:class:`~repro.runtime.transport.server.TransportServer` in another
process. It implements the same ``put`` / ``pop_batch`` surface as
:class:`~repro.runtime.experience.FifoChannel`, with the same backpressure
semantics — the *server-side* channel's policy decides, and the boolean
verdict (accepted / dropped / block-timed-out) crosses the wire:

  * ``put`` returns False iff the remote channel rejected the item;
  * ``pop_batch(n, timeout)`` blocks up to ``timeout`` (None = forever),
    long-polling the server in short slices so a concurrent ``close()``
    always unblocks it promptly (it returns None, like a timeout);
  * after ``close()``, ``put`` returns False and ``pop_batch`` returns
    None — shutdown is a data-plane no-op, not an exception storm.

:class:`ShmChannel` speaks the identical protocol but moves large payloads
out-of-band through POSIX shared memory: the socket carries only the
segment name, the bytes never transit the TCP stack. Ownership rule:
whoever *creates* a segment unlinks it, after the consuming side has
acknowledged (the reply for requests; the next frame on the same
connection for responses).

The STREAMING data plane layers two upgrades on top:

  * :class:`PutStream` — a pipelined fire-and-forget put path with
    windowed acks: sequence-numbered ``chan.put_stream`` frames go out
    without waiting for the reply, up to ``window`` frames in flight;
    backpressure verdicts come back asynchronously and are applied to
    the stream's counters instead of blocking each flush. A dropped
    connection replays the unacked window after the redial, and the
    server dedups by ``(channel, stream, seq)`` — upgrading the
    reconnect path from at-least-once to exactly-once.
  * :class:`ShmRingChannel` — per-message SHM segments replaced by TWO
    persistent :class:`~repro.runtime.transport.ring.ShmRing` segments
    per channel (client→server for streamed puts, server→client for pop
    replies): payloads cross at memcpy speed with zero per-message
    ``shm_open``/``unlink`` churn, and the server sweeps only the ring.
"""
from __future__ import annotations

import binascii
import collections
import os
import random
import socket
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.runtime.experience import ExperienceChannel
from repro.runtime.transport.codec import (decode_pytree, encode_pytree,
                                           frame_bytes, plan_pytree,
                                           recv_frame, send_frame)
from repro.runtime.transport.ring import RingError, RingView, ShmRing

try:
    from multiprocessing import shared_memory
except ImportError:  # pragma: no cover — stdlib on every target platform
    shared_memory = None

# import-gated fault injection (see transport.faults): inert — not even
# imported — unless REPRO_FAULTS is set
if os.environ.get("REPRO_FAULTS"):
    from repro.runtime.transport.faults import fault_point as _fault
else:
    _fault = None

# import-gated tracing (see runtime.telemetry, same idiom): when on, the
# active trace context rides put-frame headers (``tr``/``sp``) so the
# server can join its apply span to the producer's flush span
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:
    _tel = None

POLL_S = 0.5          # per-RPC slice of a long pop/acquire wait


def _jittered(delay: float) -> float:
    """±25% jitter on a backoff delay: N workers redialing a replaced
    server spread their attempts instead of thundering-herd the listener
    in exponential lockstep."""
    return delay * (0.75 + 0.5 * random.random())

__all__ = ["TransportError", "ChannelClosed", "WireClient", "long_poll",
           "PutStream", "SocketChannel", "ShmChannel", "ShmRingChannel",
           "RingLease", "release_lease", "shm_read", "shm_write",
           "parse_address"]


class RingLease:
    """Refcounted handle over one leased pop-reply ring record.

    A zero-copy pop decodes N items whose array leaves all view the SAME
    :class:`~repro.runtime.transport.ring.RingView`; each item carries
    this lease under ``"_lease"`` and the underlying view is released
    only when every item has been consumed (copied into a staging
    buffer) and released. Idempotent per item; thread-safe."""

    __slots__ = ("_view", "_refs", "_lock")

    def __init__(self, view: RingView, refs: int):
        self._view = view
        self._refs = max(int(refs), 1)
        self._lock = threading.Lock()

    def release(self) -> None:
        with self._lock:
            self._refs -= 1
            done = self._refs == 0
        if done:
            self._view.release()


def release_lease(item: Any) -> None:
    """Release ``item``'s ring lease, if it carries one (consumer-side
    helper: call AFTER the item's arrays have been copied out — the views
    die with the lease)."""
    if isinstance(item, dict):
        lease = item.pop("_lease", None)
        if lease is not None:
            lease.release()


def parse_address(address: str) -> Tuple[str, int]:
    """``"host:port"`` → ``(host, port)``; a bare ``":port"``/``"port"``
    falls back to loopback. The one parser every CLI/config shares."""
    host, _, port = address.rpartition(":")
    return (host or "127.0.0.1", int(port))


class TransportError(RuntimeError):
    """A wire-level failure (server error, protocol violation)."""


class ChannelClosed(TransportError):
    """The connection is gone — closed locally or by the peer."""


def shm_write(data: bytes) -> "shared_memory.SharedMemory":
    """Create a shared-memory segment holding ``data`` (caller unlinks).

    Segments carry the ``acrl<pid>x…`` naming scheme so a later server
    incarnation can sweep any that a SIGKILLed creator leaked
    (:func:`repro.runtime.transport.resilience.sweep_stale_shm`)."""
    if shared_memory is None:
        raise TransportError("shared memory unavailable on this platform")
    from repro.runtime.transport.resilience import shm_name
    while True:
        try:
            shm = shared_memory.SharedMemory(name=shm_name(), create=True,
                                             size=max(len(data), 1))
            break
        except FileExistsError:            # 32-bit token collision
            continue
    shm.buf[:len(data)] = data
    return shm


def shm_read(name: str, size: int) -> bytes:
    """Copy ``size`` bytes out of segment ``name`` (no unlink — the
    creator owns the lifetime).

    No resource-tracker compensation is needed even though attaching
    registers the name on CPython < 3.13: spawned workers INHERIT the
    parent's tracker process, so the attach registration collapses into
    the creator's (the tracker cache is a set) and the creator's unlink
    removes the single entry. A worker killed while holding segments
    leaves them to that same tracker's exit cleanup — which is the
    tracker working as intended, not a leak."""
    if shared_memory is None:
        raise TransportError("shared memory unavailable on this platform")
    shm = shared_memory.SharedMemory(name=name)
    try:
        return bytes(shm.buf[:size])
    finally:
        shm.close()


def _dial(address: Tuple[str, int], timeout: float) -> socket.socket:
    """Connect with retry-until-deadline (the server may still be
    binding), then switch to blocking + NODELAY."""
    deadline = time.monotonic() + timeout
    while True:
        try:
            sock = socket.create_connection(address,
                                            timeout=max(timeout, 0.05))
            break
        except OSError as e:
            if time.monotonic() >= deadline:
                raise TransportError(
                    f"cannot connect to transport server at "
                    f"{address}: {e}") from e
            time.sleep(0.05)
    sock.settimeout(None)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock


class WireClient:
    """One blocking request/response connection with a call lock.

    Each proxy object owns one connection; concurrent callers serialize on
    the lock (requests are short except deliberately-bounded long-polls).
    ``close()`` from any thread shuts the socket down, which unblocks a
    caller parked in ``recv`` with :class:`ChannelClosed`.

    With ``reconnect_attempts > 0`` the client survives a *server-side*
    connection drop: a failed round-trip redials with exponential backoff
    and re-issues the request up to that many times before surfacing
    :class:`ChannelClosed`. Retried requests are at-least-once — most
    server endpoints are either idempotent (``worker.report``,
    ``store.publish`` by version, ``store.state``) or tolerant of a
    duplicate (``chan.put``/``put_many``: a re-accepted segment is
    ordinary replay data). The exception is ``chan.pop``: if the reply is
    lost AFTER the server popped, the retry pops a fresh batch and the
    first one is gone — equivalent to a channel drop, acceptable for
    experience data (and remote pops are off the training hot path:
    remote workers produce, the trainer pops locally). ``on_reconnect``
    fires after each successful redial, under the call lock — proxies use
    it to bust version caches so state (e.g. the newest weight version)
    is re-acquired on the fresh connection.
    """

    def __init__(self, address: Tuple[str, int], *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 reconnect_backoff_max_s: float = 2.0,
                 on_reconnect=None):
        self.address = tuple(address)
        self._connect_timeout = connect_timeout
        self._lock = threading.Lock()
        self._shm_threshold = shm_threshold
        self._reconnect_attempts = reconnect_attempts
        self._reconnect_backoff_s = reconnect_backoff_s
        self._reconnect_backoff_max_s = reconnect_backoff_max_s
        self._on_reconnect = on_reconnect
        self.reconnects = 0
        self.closed = False
        self._sock = self._dial(connect_timeout)

    def _dial(self, timeout: float) -> socket.socket:
        return _dial(self.address, timeout)

    def raw_request(self, header: Dict, body: bytes = b"") -> Tuple[Dict,
                                                                    bytes]:
        """One UNLOCKED, no-retry round-trip on the current socket.

        Only for ``on_reconnect`` hooks, which already run under the call
        lock: a hook that needs to re-establish per-connection state
        (e.g. re-opening a ring) cannot call :meth:`request` without
        deadlocking on its own lock."""
        send_frame(self._sock, header, body)
        resp = recv_frame(self._sock)
        if resp is None:
            raise ConnectionError("server closed during handshake")
        rh, rbody = resp
        if rh.get("err"):
            raise TransportError(rh["err"])
        return rh, rbody

    def _redial(self, attempt: int) -> bool:
        """One backoff-then-reconnect try (caller holds the lock)."""
        delay = min(self._reconnect_backoff_s * (2 ** (attempt - 1)),
                    self._reconnect_backoff_max_s)
        time.sleep(_jittered(delay))
        if self.closed:
            return False
        try:
            sock = self._dial(self._connect_timeout)
        except TransportError:
            return False
        try:
            self._sock.close()
        except OSError:
            pass
        self._sock = sock
        self.reconnects += 1
        if self._on_reconnect is not None:
            try:
                self._on_reconnect()
            except Exception:          # noqa: BLE001 — a cache-bust hook
                pass                   # must never poison the data path
        return True

    def request(self, header: Dict, body: bytes = b"", *,
                oob: bool = False) -> Tuple[Dict, bytes]:
        """One round-trip. ``oob=True`` routes a large body through shared
        memory instead of the socket (the SHM data plane)."""
        shm = None
        if (oob and shared_memory is not None
                and len(body) >= self._shm_threshold):
            shm = shm_write(body)
            header = {**header, "shm": shm.name, "shm_size": len(body)}
            body = b""
        try:
            with self._lock:
                if self.closed:
                    raise ChannelClosed("transport client is closed")
                resp = None
                last: Optional[Exception] = None
                for attempt in range(self._reconnect_attempts + 1):
                    if attempt and (self.closed or not self._redial(attempt)):
                        break
                    try:
                        if _fault is not None:
                            _fault("client.request")
                        send_frame(self._sock, header, body)
                        resp = recv_frame(self._sock)
                        if resp is None:   # clean EOF: peer closed on us
                            raise ConnectionError(
                                "server closed the connection")
                        break
                    except (OSError, ValueError) as e:
                        last = e
                        resp = None
                if resp is None:
                    self.close()
                    raise ChannelClosed(
                        f"transport connection lost: {last}") from last
            rh, rbody = resp
            if rh.get("err"):
                raise TransportError(rh["err"])
            if rh.get("shm"):          # out-of-band response body
                rbody = shm_read(rh["shm"], rh["shm_size"])
            return rh, rbody
        finally:
            if shm is not None:
                shm.close()
                try:                   # server consumed it during the RTT
                    shm.unlink()
                except FileNotFoundError:
                    pass

    def close(self) -> None:
        self.closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


def long_poll(client: WireClient, make_header,
              timeout: Optional[float]) -> Optional[Tuple[Dict, bytes]]:
    """Blocking-request idiom shared by pop_batch and acquire: re-issue
    the request in bounded ``POLL_S`` slices until the server answers
    ``ok``, the deadline passes, or the client closes (→ None, so a
    concurrent ``close()`` always unblocks the caller within one slice).
    ``make_header(slice_timeout)`` builds each request; a ``timeout`` of 0
    still makes exactly one non-blocking attempt."""
    deadline = None if timeout is None else time.monotonic() + timeout
    first = True
    while not client.closed:
        remaining = (None if deadline is None
                     else deadline - time.monotonic())
        if not first and remaining is not None and remaining <= 0:
            return None
        t = (POLL_S if remaining is None
             else max(min(POLL_S, remaining), 0.0))
        first = False
        try:
            resp, body = client.request(make_header(t))
        except ChannelClosed:
            return None
        if resp.get("ok"):
            return resp, body
    return None


class PutStream:
    """Pipelined put path: fire-and-forget frames, windowed async acks.

    The synchronous ``put_many`` pays one full round-trip per flush — the
    producer idles for an RTT while the server decodes. A PutStream keeps
    up to ``window`` sequence-numbered frames in flight on a DEDICATED
    connection; a receiver thread drains the CUMULATIVE acks (the server
    replies once per ``ack_every`` frames, carrying every covered frame's
    verdicts; duplicates and ``stream.flush`` force an immediate drain)
    and applies the per-item backpressure verdicts to the stream
    counters. ``put_many`` therefore blocks only when the window is full,
    which is exactly the server falling behind — backpressure propagates
    through the window, not through per-flush latency. Frames produced
    back-to-back are additionally burst-coalesced into one ``sendall``
    (syscall + receiver wakeup dominate small frames, not bytes).

    With ``ring_bytes > 0`` the frame bodies travel through a persistent
    client→server :class:`~repro.runtime.transport.ring.ShmRing` instead
    of the socket: the frame header carries only ``ring_nbytes`` and the
    encoded blob is written straight into the ring reservation
    (:func:`~repro.runtime.transport.codec.plan_pytree`, no intermediate
    copy).

    **Delivery semantics.** Frames are idempotent by ``(channel, stream
    id, seq)``: after a connection drop the stream redials (up to
    ``reconnect_attempts``, exponential backoff), re-opens its state, and
    replays the unacked window in order; the server re-acks frames it
    already applied WITHOUT re-applying them — each flush lands in the
    channel exactly once across any number of mid-stream reconnects. A
    fresh ring is created per connection, so ring records and frames can
    never desynchronize across a replay.

    ``put_many`` returns provisional all-True verdicts for enqueued items
    (all-False once the stream is closed or failed); the authoritative
    accept/reject counts are in :meth:`stats` after the acks land —
    producers that care should ``flush()`` and read them.

    **Ownership (ring mode).** Like any zero-copy send API, a ring-mode
    stream borrows the items' array leaves until their frame is ACKED:
    the replay window keeps the encode *plan* (leaf references), so a
    reconnect re-serializes the arrays as they are THEN. Do not mutate
    or reuse buffers handed to a streamed ``put_many`` (rollout flushes
    allocate fresh segment arrays per episode, so this holds naturally).
    """

    def __init__(self, address: Tuple[str, int], chan: str, *,
                 window: int = 32, ring_bytes: int = 0,
                 ack_every: int = 0,
                 adaptive: bool = False,
                 connect_timeout: float = 20.0,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 reconnect_backoff_max_s: float = 2.0,
                 stream_id: Optional[str] = None):
        self.address = tuple(address)
        self.chan = chan
        self.window = max(int(window), 1)
        # cumulative acks: one reply per `ack_every` frames — a reply per
        # frame costs a receiver-thread wakeup (GIL handoff) per flush,
        # which measurably throttles the producer. 0 = auto (window/4),
        # capped at window/2 so acks always free the window in time.
        if ack_every <= 0:
            ack_every = max(self.window // 4, 1)
        self.ack_every = max(1, min(ack_every, max(self.window // 2, 1)))
        # adaptive streaming: tune the EFFECTIVE window/ack cadence online
        # from observed cumulative-ack RTT. The configured values are hard
        # BOUNDS — the effective window starts at the upper bound (steady
        # RTT therefore never throttles below static behavior), halves on
        # verdict pressure or an RTT spike vs the EWMA, and recovers
        # multiplicatively on low occupancy / settled RTT.
        self.adaptive = bool(adaptive)
        self._win_min = max(1, self.window // 8)
        self.window_effective = self.window
        self.ack_every_effective = self.ack_every
        self._ack_every_sent = self.ack_every   # what the server applies
        self._rtt_ewma = 0.0
        self.window_backoffs = 0
        self.stream_id = stream_id or binascii.hexlify(os.urandom(8)).decode()
        self._ring_bytes = int(ring_bytes)
        self._connect_timeout = connect_timeout
        self._reconnect_attempts = reconnect_attempts
        self._reconnect_backoff_s = reconnect_backoff_s
        self._reconnect_backoff_max_s = reconnect_backoff_max_s
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # seq -> (encoded blob, item count, trace ctx or None); kept
        # until acked so a reconnect can replay the window — the ctx
        # rides along so replayed frames keep their trace ids
        self._pending: "collections.OrderedDict[int, Tuple]" = \
            collections.OrderedDict()
        self._next_seq = 0
        self.closed = False
        self.failed: Optional[str] = None
        self._ring: Optional[ShmRing] = None
        # burst coalescing: frames produced back-to-back are shipped
        # several per sendall — the syscall + receiver wakeup, not the
        # bytes, dominate small frames (see _maybe_flush_sendbuf)
        self._sendbuf = bytearray()
        self._sendbuf_frames = 0
        self._last_append = 0.0
        self.items_enqueued = 0
        self.items_acked = 0
        self.items_accepted = 0
        self.items_rejected = 0
        self.frames_sent = 0
        self.replayed_frames = 0
        self.reconnects = 0
        self._sock = _dial(self.address, connect_timeout)
        # buffered ack reader: many small acks per recv syscall
        self._rfile = self._sock.makefile("rb")
        self._open()
        self._recv_thread = threading.Thread(
            target=self._recv_loop, daemon=True,
            name=f"putstream-{chan}")
        self._recv_thread.start()
        self._flush_thread = threading.Thread(
            target=self._flush_loop, daemon=True,
            name=f"putstream-flush-{chan}")
        self._flush_thread.start()

    # -- connection (re)establishment -----------------------------------------
    def _open(self) -> None:
        """Handshake the stream on the current socket: announce the
        stream id (dedup key) and, in ring mode, a FRESH ring."""
        ring = None
        if self._ring_bytes:
            ring = ShmRing.create(self._ring_bytes)
        header = {"m": "stream.open", "chan": self.chan,
                  "stream": self.stream_id, "window": self.window,
                  "ack_every": self.ack_every_effective}
        self._ack_every_sent = self.ack_every_effective
        if ring is not None:
            header["ring"] = ring.name
        try:
            # bounded handshake: _open may run under the stream lock (a
            # reconnect), so a server dying mid-accept must not wedge it
            self._sock.settimeout(max(self._connect_timeout, 1.0))
            send_frame(self._sock, header)
            resp = recv_frame(self._rfile)
            if resp is None:
                raise ConnectionError("server closed during stream.open")
            if resp[0].get("err"):
                raise TransportError(resp[0]["err"])
            self._sock.settimeout(None)
        except BaseException:
            if ring is not None:
                ring.close()
                ring.unlink()
            raise
        old, self._ring = self._ring, ring
        if old is not None:
            old.close()
            old.unlink()

    def _flush_sendbuf(self) -> None:
        """Ship every coalesced frame in one sendall (caller holds the
        lock)."""
        if self._sendbuf:
            buf, self._sendbuf = self._sendbuf, bytearray()
            self._sendbuf_frames = 0
            self._sock.sendall(buf)

    def _send_frame(self, seq: int, payload, count: int,
                    ctx: Optional[Dict] = None) -> None:
        """Caller holds the lock. Ring mode writes the encoded blob
        straight into the ring reservation (``payload`` is an
        :class:`~repro.runtime.transport.codec.EncodePlan`, no
        intermediate ``bytes``) and commits BEFORE the frame that
        references it goes out; socket mode carries ``payload`` bytes as
        the frame body. Frames are appended to the coalescing buffer —
        :meth:`_maybe_flush_sendbuf` / :meth:`_flush_sendbuf` ship it."""
        if _fault is not None:
            _fault("client.stream_send")
        header = {"m": "chan.put_stream", "chan": self.chan,
                  "stream": self.stream_id, "seq": seq, "count": count}
        if ctx:
            header.update(ctx)             # tr/sp trace ids ride the frame
        if self._ring is not None:
            view = self._ring.reserve(payload.nbytes, timeout=0)
            if view is None:
                # ring full: the server can only drain records whose
                # control frames it has SEEN — ship the coalescing
                # buffer before blocking, or a replay (many reserves,
                # frames all buffered) wedges against its own ring
                self._flush_sendbuf()
                view = self._ring.reserve(payload.nbytes, timeout=30.0)
            if view is None:
                raise RingError("put ring stalled (server not draining)")
            try:
                payload.write_into(view)
            finally:
                view.release()
            self._ring.commit()
            header["ring_nbytes"] = payload.nbytes
            self._sendbuf += frame_bytes(header)
            self._sendbuf_frames += 1
        elif len(payload) > (1 << 16):
            # big body: no copy into the buffer — flush and send direct
            self._flush_sendbuf()
            send_frame(self._sock, header, payload)
        else:
            self._sendbuf += frame_bytes(header, payload)
            self._sendbuf_frames += 1
        self.frames_sent += 1

    #: burst-coalescing caps: ship after this many frames or bytes. Each
    #: sendall is a syscall AND a peer wakeup (which on a busy box can
    #: preempt the producer), so bigger bursts help until the window
    #: (acks lag a full burst) or latency (one burst of staging) bind.
    COALESCE_FRAMES = 16
    COALESCE_BYTES = 1 << 17

    def _maybe_flush_sendbuf(self) -> None:
        """Burst-aware shipping (caller holds the lock): coalesce frames
        while puts arrive back-to-back (< 2 ms apart); a put after a
        pause ships immediately, so a slow producer (one episode at a
        time) never sees added latency. A burst's unshipped tail is
        bounded by :meth:`_flush_loop` (≈2 ms), a window wait,
        ``flush()``, or ``close()``."""
        now = time.monotonic()
        if (self._sendbuf_frames >= min(self.COALESCE_FRAMES, self.window)
                or len(self._sendbuf) >= self.COALESCE_BYTES
                or now - self._last_append > 0.002):
            self._flush_sendbuf()
        self._last_append = now

    def _flush_loop(self) -> None:
        """Deadline flusher: a burst's tail must not sit in the
        coalescing buffer waiting for the NEXT put — a producer that
        bursts then goes quiet (several envs flushing together, then a
        long episode) would otherwise strand committed experience
        client-side indefinitely. Idle cost is one 4 Hz poll."""
        with self._cv:
            while not self.closed:
                if not self._sendbuf:
                    self._cv.wait(timeout=0.25)
                    continue
                self._cv.wait(timeout=0.002)
                if (self._sendbuf and not self.closed
                        and time.monotonic() - self._last_append >= 0.002):
                    try:
                        self._flush_sendbuf()
                    except (OSError, ValueError):
                        pass           # the recv loop owns the redial

    # -- producer surface -----------------------------------------------------
    def put_many(self, items: List[Any]) -> List[bool]:
        """Enqueue one flush; blocks only while the ack window is full.
        Verdicts are provisional (see class docstring)."""
        items = list(items)
        if not items:
            return []
        # ring mode keeps the PLAN (schema + leaf refs) pending, not a
        # serialized copy — the bytes only ever materialize inside the
        # ring; socket mode needs real bytes for the frame body
        payload = (plan_pytree(items) if self._ring_bytes
                   else encode_pytree(items))
        # oversize is a CONFIG error (ring too small for one flush), not
        # a transport failure — surface it loudly instead of retrying
        if self._ring is not None and (payload.nbytes
                                       > self._ring.max_record()):
            raise RingError(
                f"flush of {payload.nbytes} bytes exceeds ring record "
                f"max {self._ring.max_record()}; raise ring_bytes or "
                f"flush smaller batches")
        with self._cv:
            waited = 0.0
            while (len(self._pending) >= self.window_effective
                   and not self.closed and self.failed is None):
                try:                       # acks can't arrive for frames
                    self._flush_sendbuf()  # still sitting in the buffer
                except OSError:
                    pass                   # recv loop owns the redial
                self._cv.wait(timeout=0.1)
                waited += 0.1
                if waited >= 0.5:          # defensive nudge: force a
                    self._request_acks()   # cumulative-ack drain
                    waited = 0.0
            if self.closed or self.failed is not None:
                return [False] * len(items)
            ctx = _tel.wire_ctx() if _tel is not None else None
            seq = self._next_seq
            self._next_seq += 1
            self._pending[seq] = (payload, len(items), ctx,
                                  time.monotonic())
            self.items_enqueued += len(items)
            try:
                self._send_frame(seq, payload, len(items), ctx)
                self._maybe_flush_sendbuf()
                if self._sendbuf:          # wake the deadline flusher so
                    self._cv.notify_all()  # a burst tail ships in ~2ms
            except (OSError, ValueError, RingError):
                # leave the frame pending: wake the receiver, which owns
                # the redial-and-replay path
                try:
                    self._sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
        return [True] * len(items)

    def put(self, item: Any) -> bool:
        return self.put_many([item])[0]

    def _request_acks(self) -> None:
        """Ask the server to drain its accumulated cumulative acks now
        (caller holds the lock; idempotent, loss-tolerant). Ships any
        coalesced frames first so the drain covers them."""
        try:
            self._flush_sendbuf()
            send_frame(self._sock, {"m": "stream.flush", "chan": self.chan,
                                    "stream": self.stream_id})
        except (OSError, ValueError):
            pass                           # the recv loop handles redials

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Wait until every in-flight frame is acked; False on timeout or
        stream failure (unacked frames remain in :meth:`stats`). Sends a
        ``stream.flush`` nudge so a tail shorter than ``ack_every`` is
        acked immediately rather than lingering."""
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        last_nudge = 0.0
        with self._cv:
            while (self._pending and self.failed is None
                   and not self.closed):
                now = time.monotonic()
                if now - last_nudge >= 0.2:
                    self._request_acks()
                    last_nudge = now
                remaining = (None if deadline is None
                             else deadline - now)
                if remaining is not None and remaining <= 0:
                    break
                self._cv.wait(timeout=(0.05 if remaining is None
                                       else min(0.05, remaining)))
            return not self._pending

    # -- ack receiver ---------------------------------------------------------
    def _recv_loop(self) -> None:
        while True:
            try:
                frame = recv_frame(self._rfile)
            except (OSError, ValueError):
                frame = None
            if frame is None:
                with self._cv:
                    if self.closed:
                        return
                if not self._reconnect():
                    return
                continue
            rh, _ = frame
            if rh.get("err"):
                with self._cv:
                    self.failed = str(rh["err"])
                    self._cv.notify_all()
                return
            acks = rh.get("acks")
            if not acks:
                continue                   # stream.open reply / empty drain
            with self._cv:
                now = time.monotonic()
                rtt = None
                rejected = 0
                for key, verdicts in acks.items():
                    entry = self._pending.pop(int(key), None)
                    if entry is None:
                        continue
                    count = entry[1]
                    rtt = now - entry[3]   # newest ack wins: one sample
                    verdicts = [bool(v) for v in verdicts]
                    verdicts += [False] * (count - len(verdicts))
                    accepted = sum(verdicts[:count])
                    self.items_acked += count
                    self.items_accepted += accepted
                    self.items_rejected += count - accepted
                    rejected += count - accepted
                if self.adaptive and rtt is not None:
                    self._tune(rtt, rejected)
                self._cv.notify_all()

    def _tune(self, rtt: float, rejected: int) -> None:
        """One adaptive-window step (caller holds the lock; one call per
        cumulative-ack batch). Backoff halves the effective window on
        verdict pressure (the server channel is shedding load — pushing a
        deeper pipeline at it only grows the replay window) or an RTT
        spike past 2x the EWMA (the server stopped keeping up); recovery
        is multiplicative, on low window occupancy or on RTT back at/below
        the EWMA. The server's ack cadence follows via ``stream.tune`` so
        a shrunken window still gets acks in time to free itself."""
        ewma = self._rtt_ewma
        self._rtt_ewma = rtt if ewma <= 0.0 else 0.8 * ewma + 0.2 * rtt
        eff = self.window_effective
        if rejected or (ewma > 0.0 and rtt > 2.0 * ewma):
            eff = max(self._win_min, eff // 2)
            if eff < self.window_effective:
                self.window_backoffs += 1
        elif (len(self._pending) * 2 <= eff or rtt <= self._rtt_ewma):
            eff = min(self.window, max(eff + 1, (eff * 3) // 2))
        self.window_effective = eff
        self.ack_every_effective = max(
            1, min(self.ack_every, max(eff // 2, 1)))
        if self.ack_every_effective != self._ack_every_sent:
            self._ack_every_sent = self.ack_every_effective
            try:
                self._sendbuf += frame_bytes(
                    {"m": "stream.tune", "chan": self.chan,
                     "stream": self.stream_id,
                     "ack_every": self.ack_every_effective})
                self._sendbuf_frames += 1
                self._flush_sendbuf()
            except (OSError, ValueError):
                pass                       # the recv loop owns the redial

    def _reconnect(self) -> bool:
        """Redial with backoff, re-open the stream, replay the unacked
        window in order (receiver thread only). The server dedups by
        seq, so already-applied frames are re-acked, not re-applied."""
        for attempt in range(1, self._reconnect_attempts + 1):
            time.sleep(_jittered(min(
                self._reconnect_backoff_s * (2 ** (attempt - 1)),
                self._reconnect_backoff_max_s)))
            with self._cv:
                if self.closed:
                    return False
            try:
                sock = _dial(self.address, self._connect_timeout)
            except TransportError:
                continue
            with self._cv:
                if self.closed:
                    sock.close()
                    return False
                for closer in (self._rfile.close, self._sock.close):
                    try:
                        closer()
                    except OSError:
                        pass
                self._sock = sock
                self._rfile = sock.makefile("rb")
                # frames parked in the coalescing buffer died with the
                # old socket; they are still pending, so the replay below
                # re-serializes them
                self._sendbuf = bytearray()
                self._sendbuf_frames = 0
                try:
                    self._open()
                    now = time.monotonic()
                    for seq, entry in list(self._pending.items()):
                        payload, count, ctx = entry[0], entry[1], entry[2]
                        # refresh t_sent: a replayed frame's RTT clock
                        # starts at the replay, not the original send
                        self._pending[seq] = (payload, count, ctx, now)
                        self._send_frame(seq, payload, count, ctx)
                        self.replayed_frames += 1
                    self._flush_sendbuf()
                except (OSError, ValueError, TransportError, RingError):
                    continue
                self.reconnects += 1
                self._cv.notify_all()
                return True
        with self._cv:
            if self.failed is None:
                self.failed = "connection lost (reconnect budget exhausted)"
            self._cv.notify_all()
        return False

    # -- introspection / lifecycle --------------------------------------------
    def stats(self) -> Dict[str, float]:
        with self._lock:
            out = {
                "items_enqueued": float(self.items_enqueued),
                "items_acked": float(self.items_acked),
                "items_accepted": float(self.items_accepted),
                "items_rejected": float(self.items_rejected),
                "frames_sent": float(self.frames_sent),
                "frames_unacked": float(len(self._pending)),
                "replayed_frames": float(self.replayed_frames),
                "reconnects": float(self.reconnects),
                "window": float(self.window),
                "window_effective": float(self.window_effective),
                "ack_every_effective": float(self.ack_every_effective),
                "window_backoffs": float(self.window_backoffs),
                "rtt_ewma_s": float(self._rtt_ewma),
            }
        return out

    def close(self, flush_timeout: float = 5.0) -> None:
        """Drain the window (best effort), then tear down the connection
        and unlink the ring."""
        self.flush(flush_timeout)
        with self._cv:
            if self.closed:
                return
            self.closed = True
            self._cv.notify_all()
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._recv_thread.join(timeout=5.0)
        self._flush_thread.join(timeout=5.0)
        for closer in (self._rfile.close, self._sock.close):
            try:
                closer()
            except OSError:
                pass
        if self._ring is not None:
            self._ring.close()
            self._ring.unlink()


class SocketChannel(ExperienceChannel):
    """Remote ExperienceChannel proxy: TCP data plane.

    ``put_window > 0`` switches the put path from one round-trip per
    flush to a :class:`PutStream` (pipelined frames, windowed async
    acks) on a dedicated second connection — ``put``/``put_many`` then
    return provisional verdicts and the authoritative accept/reject
    counts live in ``stream_stats()``.
    """

    #: whether payload bodies travel out-of-band (overridden by ShmChannel)
    oob = False

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 put_window: int = 0,
                 ring_bytes: int = 0,
                 adaptive_window: bool = False):
        self.name = name
        self.address = tuple(address)
        self._connect_timeout = connect_timeout
        self._reconnect_attempts = reconnect_attempts
        self._reconnect_backoff_s = reconnect_backoff_s
        self._put_window = int(put_window)
        self._ring_bytes = int(ring_bytes)
        self._adaptive_window = bool(adaptive_window)
        self._stream: Optional[PutStream] = None
        self._stream_failed_at = 0.0
        self._stream_lock = threading.Lock()
        self._client = WireClient(address, connect_timeout=connect_timeout,
                                  shm_threshold=shm_threshold,
                                  reconnect_attempts=reconnect_attempts,
                                  reconnect_backoff_s=reconnect_backoff_s,
                                  on_reconnect=self._on_wire_reconnect)

    # hooks the ring subclass overrides ---------------------------------------
    def _on_wire_reconnect(self) -> None:
        """Re-establish per-connection server state after a redial."""

    def _pop_request_extra(self) -> Dict:
        return {}

    def _pop_payload(self, resp: Dict, body: bytes) -> bytes:
        return body

    def _decode_pop(self, resp: Dict, body: bytes) -> List[Any]:
        """Decode one pop reply (hook: the ring subclass decodes straight
        out of a leased ring view when zero-copy pops are enabled)."""
        return decode_pytree(self._pop_payload(resp, body))

    # -- streaming put path ---------------------------------------------------
    def _put_stream(self) -> PutStream:
        with self._stream_lock:
            if self._stream is None:
                if self._client.closed:
                    raise ChannelClosed("transport client is closed")
                # a failed construction already ate a full dial deadline;
                # fail fast for a holdoff instead of re-paying it on
                # every flush while the server is down
                if time.monotonic() - self._stream_failed_at < 5.0:
                    raise ChannelClosed(
                        "put stream unavailable (recent dial failure)")
                try:
                    self._stream = PutStream(
                        self.address, self.name, window=self._put_window,
                        ring_bytes=self._ring_bytes,
                        adaptive=self._adaptive_window,
                        connect_timeout=self._connect_timeout,
                        reconnect_attempts=self._reconnect_attempts,
                        reconnect_backoff_s=self._reconnect_backoff_s)
                except (TransportError, OSError):
                    self._stream_failed_at = time.monotonic()
                    raise
            return self._stream

    def stream_stats(self) -> Optional[Dict[str, float]]:
        """The put stream's counters (None before the first streamed
        put): authoritative accepted/rejected once acks land."""
        with self._stream_lock:
            return None if self._stream is None else self._stream.stats()

    # -- ExperienceChannel surface -------------------------------------------
    def put(self, item: Any) -> bool:
        if self._put_window > 0:
            try:
                return self._put_stream().put(item)
            except (TransportError, OSError):
                return False
        header = {"m": "chan.put", "chan": self.name}
        if _tel is not None:
            header.update(_tel.wire_ctx())
        try:
            resp, _ = self._client.request(header, encode_pytree(item),
                                           oob=self.oob)
        except ChannelClosed:
            return False
        return bool(resp.get("ok"))

    def put_many(self, items: List[Any]) -> List[bool]:
        """Batched put: ONE codec blob + one round-trip for the whole
        flush; the server answers a per-item verdict vector from the
        hosted channel's own backpressure policy. With ``put_window``
        the flush is instead pipelined through the put stream."""
        items = list(items)
        if not items:
            return []
        if self._put_window > 0:
            try:
                return self._put_stream().put_many(items)
            except RingError:
                raise                 # config error: surface it loudly
            except (TransportError, OSError):
                return [False] * len(items)
        header = {"m": "chan.put_many", "chan": self.name,
                  "count": len(items)}
        if _tel is not None:
            header.update(_tel.wire_ctx())
        try:
            resp, _ = self._client.request(header, encode_pytree(items),
                                           oob=self.oob)
        except ChannelClosed:
            return [False] * len(items)
        verdicts = [bool(v) for v in resp.get("verdicts", ())]
        # a malformed reply must not fabricate acceptance
        verdicts += [False] * (len(items) - len(verdicts))
        return verdicts[:len(items)]

    def pop_batch(self, n: int, timeout: Optional[float] = None
                  ) -> Optional[List[Any]]:
        got = long_poll(
            self._client,
            lambda t: {"m": "chan.pop", "chan": self.name, "n": n,
                       "timeout": t, "want_shm": self.oob,
                       **self._pop_request_extra()},
            timeout)
        if got is None:
            return None
        return self._decode_pop(*got)

    def pop_many(self, max_items: int, timeout: Optional[float] = None
                 ) -> Optional[List[Any]]:
        """Coalesced drain: everything available (≤ ``max_items``) in ONE
        RPC and one codec blob — no per-item round-trips, no separate
        ``len`` probe. Blocks up to ``timeout`` only for the first item."""
        got = long_poll(
            self._client,
            lambda t: {"m": "chan.pop_many", "chan": self.name,
                       "n": max_items, "timeout": t, "want_shm": self.oob,
                       **self._pop_request_extra()},
            timeout)
        if got is None:
            return None
        return self._decode_pop(*got)

    def __len__(self) -> int:
        try:
            resp, _ = self._client.request({"m": "chan.len",
                                            "chan": self.name})
        except ChannelClosed:
            return 0
        return int(resp["len"])

    def stats(self) -> Dict[str, float]:
        try:
            resp, _ = self._client.request({"m": "chan.stats",
                                            "chan": self.name})
        except ChannelClosed:
            return {"depth": 0.0}
        return {k: float(v) for k, v in resp["stats"].items()}

    # -- lifecycle ------------------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._client.closed

    def close(self) -> None:
        """Tear the connection down; a blocked ``pop_batch`` returns None
        within one poll slice, subsequent ``put``s return False."""
        with self._stream_lock:
            stream, self._stream = self._stream, None
        if stream is not None:
            stream.close()
        self._client.close()


class ShmChannel(SocketChannel):
    """SocketChannel with a shared-memory data plane for large payloads.

    The control messages (verdicts, lengths, small items under the
    threshold) still ride the socket; anything bigger moves through a
    per-message SHM segment, so segment batches and weight payloads cross
    the boundary at memcpy speed.
    """

    oob = True

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 put_window: int = 0,
                 adaptive_window: bool = False):
        if shared_memory is None:
            raise TransportError(
                "ShmChannel needs multiprocessing.shared_memory")
        super().__init__(address, name, connect_timeout=connect_timeout,
                         shm_threshold=shm_threshold,
                         reconnect_attempts=reconnect_attempts,
                         reconnect_backoff_s=reconnect_backoff_s,
                         put_window=put_window,
                         adaptive_window=adaptive_window)


class ShmRingChannel(SocketChannel):
    """SocketChannel with a PERSISTENT shared-memory ring data plane.

    Where :class:`ShmChannel` creates/attaches/unlinks one SHM segment
    per message, this channel creates exactly TWO ring segments at
    construction and reuses them for every payload:

      * puts are always streamed (:class:`PutStream` with a
        client→server ring): encoded flushes are written straight into
        the ring reservation and the socket frames carry only
        ``(seq, ring_nbytes)``;
      * pop replies travel through a server→client ring (``want_ring``):
        the server pushes the blob and answers ``ring_nbytes``; if the
        ring is unavailable (stalled or not yet re-opened after a
        redial) the reply transparently falls back in-band.

    Rings live exactly as long as their connection: a reconnect creates
    fresh rings (the unacked put window is replayed into the new one),
    and whichever side outlives the other unlinks — the server sweeps a
    dead client's rings when the connection dies, instead of keeping an
    LRU of per-message orphan names.
    """

    oob = False    # payload never rides per-message segments here

    def __init__(self, address: Tuple[str, int], name: str, *,
                 connect_timeout: float = 20.0,
                 shm_threshold: int = 1 << 16,
                 reconnect_attempts: int = 0,
                 reconnect_backoff_s: float = 0.1,
                 put_window: int = 32,
                 ring_bytes: int = 8 << 20,
                 adaptive_window: bool = False,
                 zero_copy_pop: bool = False):
        if shared_memory is None:
            raise TransportError(
                "ShmRingChannel needs multiprocessing.shared_memory")
        self._s2c: Optional[ShmRing] = None
        # opt-in zero-copy pops: decoded items view the ring in place and
        # carry a RingLease the CONSUMER must release after copying the
        # arrays out (the Prefetcher does, after collate). Off by
        # default: a consumer that drops items on the floor would pin the
        # ring and stall subsequent pop replies.
        self.zero_copy_pop = bool(zero_copy_pop)
        super().__init__(address, name, connect_timeout=connect_timeout,
                         shm_threshold=shm_threshold,
                         reconnect_attempts=reconnect_attempts,
                         reconnect_backoff_s=reconnect_backoff_s,
                         put_window=max(int(put_window), 1),
                         ring_bytes=int(ring_bytes),
                         adaptive_window=adaptive_window)
        self._open_pop_ring(self._client.request)

    def _open_pop_ring(self, request) -> None:
        """Create a fresh pop-reply ring and hand it to the server side
        of the CURRENT connection (``request`` is ``client.request`` at
        construction, ``client.raw_request`` from the reconnect hook)."""
        ring = ShmRing.create(self._ring_bytes)
        try:
            request({"m": "ring.open", "s2c": ring.name})
        except BaseException:
            ring.close()
            ring.unlink()
            raise
        old, self._s2c = self._s2c, ring
        if old is not None:
            old.close()
            old.unlink()

    def _on_wire_reconnect(self) -> None:
        # runs under the WireClient call lock → must use raw_request
        self._open_pop_ring(self._client.raw_request)

    def _pop_request_extra(self) -> Dict:
        return {"want_ring": True} if self._s2c is not None else {}

    def _pop_payload(self, resp: Dict, body: bytes) -> bytes:
        nbytes = resp.get("ring_nbytes")
        if nbytes is None:
            return body               # server fell back in-band
        got = self._s2c.pop(timeout=5.0)
        if got is None or len(got) != nbytes:
            raise TransportError(
                f"pop reply ring record missing/short (want {nbytes})")
        return got

    def _decode_pop(self, resp: Dict, body: bytes) -> List[Any]:
        """Zero-copy decode path: lease the pop-reply ring record in
        place, decode over the live view, and stamp each item with the
        shared :class:`RingLease`. Wraparound-split records come back
        already copied (the lease is a no-op); non-dict items cannot
        carry a lease and fall back to an owned copy."""
        nbytes = resp.get("ring_nbytes")
        if not self.zero_copy_pop or nbytes is None:
            return super()._decode_pop(resp, body)
        view = self._s2c.pop_view(timeout=5.0)
        if view is None or view.nbytes != nbytes:
            if view is not None:
                view.release()
            raise TransportError(
                f"pop reply ring record missing/short (want {nbytes})")
        if view.copied:               # split fallback: owned bytes already
            return decode_pytree(view.data)
        items = decode_pytree(view.data)
        if not items or not all(isinstance(it, dict) for it in items):
            out = decode_pytree(bytes(view.data))
            view.release()
            return out
        lease = RingLease(view, len(items))
        for item in items:
            item["_lease"] = lease
        return items

    def ring_stats(self) -> Dict[str, float]:
        return {} if self._s2c is None else self._s2c.stats()

    def close(self) -> None:
        super().close()
        if self._s2c is not None:
            self._s2c.close()
            self._s2c.unlink()
