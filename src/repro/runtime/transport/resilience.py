"""Resilient control plane: journaled TransportServer state + recovery.

PRs 3-5 made *workers* disposable (restart budgets, redial-to-rejoin,
exactly-once stream replay), but the parent ``TransportServer`` remained
a single point of failure: its death lost every hosted channel, the
weight store, and all per-stream dedup watermarks. This module removes
that: a write-ahead **journal** records every state mutation the server
hosts, periodic **compacting snapshots** bound replay time, and a
replacement server (``--resume-journal``) recovers to the last committed
record — so an in-flight :class:`~repro.runtime.transport.channel.PutStream`
window replays exactly-once across a server *death*, not just a
connection drop.

File format (``<dir>/log-<gen>.bin`` + ``snap-<gen>.bin``, both starting
with the 8-byte magic)::

    record := u32 payload_len | u32 crc32(payload) | payload
    payload := u32 header_len | header_json | body

``header_json`` carries ``{"op": ..., ...}``; ``body`` is an opaque codec
blob. Appends **group-commit**: records accumulate in a pending buffer
and are written — one ``write(2)`` for the whole batch — at every commit
point: before any wire reply or cumulative stream ack leaves the server,
after a journaled pop hands items to a local consumer, on weight
publishes, and on an idle-tick timer. Between commit points nothing
external depends on a buffered record, so a crash loses only frames
whose ack never left — which the producer replays. The page cache is
the durability domain: it survives a SIGKILLed *process*, which is the
failure this journal defends — machine-level durability would need
``fsync`` per commit and is deliberately out of scope (snapshots DO
fsync). A torn final record (crc or length mismatch) marks the end of
the committed prefix and is discarded on recovery.

Journaled operations and their replay semantics:

  ============  ===========================================================
  ``chan_meta``  declares a channel's capacity + backpressure policy so
                 replay can emulate evictions
  ``put``        the ACCEPTED items of one flush (rejected items never
                 enter the journal); replay appends and applies
                 ``drop_oldest`` eviction at capacity. A streamed flush
                 FUSES its dedup watermark into the same record
                 (``stream``/``seq``/``verdicts`` header keys): one
                 append per frame, and items + watermark are atomic by
                 construction — a crash can never recover the items
                 without the watermark that dedups their replay
  ``pop``        ``n`` items left the front of the channel
  ``stream``     a put-stream dedup watermark ``(chan, stream, seq)``
                 + its verdicts alone (streamed frames into channels the
                 journal does not wrap) — replay keeps the max seq
                 (idempotent)
  ``stream_snap``  a full stream-state capture (snapshot compaction)
  ``publish``    a weight-store publish: version + encoded params blob
                 — replay keeps the newest version (idempotent)
  ``snap_end``   snapshot validity marker (a snapshot without one is an
                 interrupted compaction and is ignored)
  ============  ===========================================================

**Write ordering.** Every mutation is *apply-then-append* under a
per-channel wrapper lock (:class:`JournaledChannel`), so the journal
never claims an op the in-memory state has not performed. The one
crash window this leaves — applied but not yet journaled, then SIGKILL —
is healed by the data path itself: the producer never received an ack
for that frame, so it replays it to the replacement server, whose
recovered watermark does not cover it, and it is applied exactly once.
Wire pops are at-most-once across a server death (a reply lost after the
journal append loses that batch — equivalent to a channel drop, which
experience data tolerates by design).

**Compaction.** ``compact()`` takes every channel wrapper lock (sorted
order — the global lock order is ``stream lock < channel wrapper lock <
journal lock``), rotates to a fresh log generation, captures channel
contents while still holding the locks (so no put/pop can straddle the
rotation), then captures stream/store state *after* the rotation —
those records are idempotent, so one landing in the soon-deleted old log
is covered by the later capture. The snapshot is written to a temp file,
fsynced, renamed, and only then are older generations deleted — a crash
at any point leaves a recoverable chain (``snap-g`` + ``log-g`` +
``log-g+1``…).

Also here: the ``acrl<pid>x<token>`` SHM naming scheme and
:func:`sweep_stale_shm`, which a starting server runs to unlink segments
and rings leaked by a SIGKILLed previous incarnation (only names whose
creator pid is dead are touched, so concurrent runs on one host are
safe).
"""
from __future__ import annotations

import binascii
import dataclasses
import json
import os
import pathlib
import re
import struct
import threading
import time
import zlib
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

from repro.runtime.transport.codec import decode_pytree, encode_pytree

__all__ = ["JOURNAL_MAGIC", "TransportJournal", "JournaledChannel",
           "RecoveredState", "read_records", "recover", "shm_name",
           "sweep_stale_shm", "SHM_NAME_PREFIX"]

JOURNAL_MAGIC = b"ACRLJRN1"
_REC = struct.Struct("<II")                    # payload_len, crc32
_HLEN = struct.Struct("<I")                    # header_json length
_GEN_RE = re.compile(r"^(log|snap)-(\d{8})\.bin$")

#: hard ceiling on one record (a flush blob is ~MBs at most; a length
#: beyond this is corruption, not data)
MAX_RECORD = 1 << 31


# ---------------------------------------------------------------------------
# SHM hygiene: nameable segments + the stale sweep
# ---------------------------------------------------------------------------

SHM_NAME_PREFIX = "acrl"


def shm_name() -> str:
    """A segment name that encodes its creator pid (``acrl<pidhex>x<tok>``)
    so :func:`sweep_stale_shm` can tell live segments from leaks."""
    return (f"{SHM_NAME_PREFIX}{os.getpid():x}x"
            f"{binascii.hexlify(os.urandom(4)).decode()}")


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:            # exists, owned by someone else
        return True
    except OSError:
        return True
    return True


def sweep_stale_shm() -> int:
    """Unlink ``acrl``-named SHM segments whose creator pid is dead — the
    rings and payload segments a SIGKILLed previous server (or worker)
    incarnation leaked. Linux-only (``/dev/shm``); a no-op elsewhere.
    Returns the number of segments removed."""
    base = pathlib.Path("/dev/shm")
    if not base.is_dir():
        return 0
    swept = 0
    for p in base.glob(SHM_NAME_PREFIX + "*"):
        pid_hex, sep, _ = p.name[len(SHM_NAME_PREFIX):].partition("x")
        if not sep:
            continue
        try:
            pid = int(pid_hex, 16)
        except ValueError:
            continue
        if pid <= 0 or _pid_alive(pid):
            continue
        try:
            p.unlink()
            swept += 1
        except OSError:
            pass
    return swept


# ---------------------------------------------------------------------------
# record framing
# ---------------------------------------------------------------------------

def _record_bytes(op: str, header: Optional[Dict] = None,
                  body: bytes = b"") -> bytes:
    hdr = dict(header or ())
    hdr["op"] = op
    hjson = json.dumps(hdr, separators=(",", ":")).encode()
    payload = b"".join((_HLEN.pack(len(hjson)), hjson, body))
    return _REC.pack(len(payload), zlib.crc32(payload)) + payload


def read_records(path: pathlib.Path
                 ) -> Tuple[List[Tuple[Dict, bytes]], bool, int]:
    """Parse one journal/snapshot file. Returns ``(records, torn,
    valid_bytes)`` — ``torn`` is True iff the file ends in a partial or
    corrupt record; ``valid_bytes`` is the length of the committed prefix
    (magic included), i.e. where an append may safely continue."""
    data = path.read_bytes()
    if len(data) < len(JOURNAL_MAGIC) or not data.startswith(JOURNAL_MAGIC):
        return [], bool(data), 0
    records: List[Tuple[Dict, bytes]] = []
    off = len(JOURNAL_MAGIC)
    while off < len(data):
        if off + _REC.size > len(data):
            return records, True, off
        plen, crc = _REC.unpack_from(data, off)
        start, end = off + _REC.size, off + _REC.size + plen
        if plen < _HLEN.size or plen > MAX_RECORD or end > len(data):
            return records, True, off
        payload = data[start:end]
        if zlib.crc32(payload) != crc:
            return records, True, off
        hlen, = _HLEN.unpack_from(payload, 0)
        if _HLEN.size + hlen > plen:
            return records, True, off
        try:
            hdr = json.loads(payload[_HLEN.size:_HLEN.size + hlen])
        except ValueError:
            return records, True, off
        records.append((hdr, bytes(payload[_HLEN.size + hlen:])))
        off = end
    return records, False, off


# ---------------------------------------------------------------------------
# the journal
# ---------------------------------------------------------------------------

def _scan_generations(directory: pathlib.Path) -> Dict[str, List[int]]:
    gens: Dict[str, List[int]] = {"log": [], "snap": []}
    if directory.is_dir():
        for p in directory.iterdir():
            m = _GEN_RE.match(p.name)
            if m:
                gens[m.group(1)].append(int(m.group(2)))
    gens["log"].sort()
    gens["snap"].sort()
    return gens


class TransportJournal:
    """Sequenced append log + compacting snapshots for hosted state.

    Thread-safe: appends serialize on an internal lock; channel mutations
    additionally serialize apply-then-append on their
    :class:`JournaledChannel` wrapper lock. ``resume=True`` continues an
    existing directory (truncating a torn tail before appending);
    ``resume=False`` on a non-empty journal directory raises rather than
    silently shadowing recoverable state."""

    def __init__(self, directory, *, compact_bytes: int = 64 << 20,
                 resume: bool = False):
        self.directory = pathlib.Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.compact_bytes = int(compact_bytes)
        self._lock = threading.Lock()
        self._compact_lock = threading.Lock()
        self._pub_lock = threading.Lock()
        self._channels: Dict[str, "JournaledChannel"] = {}
        self._last_publish: Optional[Tuple[int, bytes]] = None
        self._pending = bytearray()
        self.records_appended = 0
        self.flushes = 0
        self.compactions = 0
        self.torn_truncated = 0
        self.closed = False
        gens = _scan_generations(self.directory)
        existing = gens["log"] or gens["snap"]
        if existing and not resume:
            raise ValueError(
                f"journal directory {self.directory} already holds "
                f"state (gen {max(gens['log'] + gens['snap'])}); pass "
                f"resume=True (--resume-journal) to continue it, or "
                f"point journal_dir at a fresh directory")
        self.gen = max(gens["log"] + gens["snap"], default=0)
        self._file: Optional[Any] = None
        self._log_bytes = 0
        self._open_log(self.gen, fresh=not existing)

    # -- file plumbing --------------------------------------------------------
    def _log_path(self, gen: int) -> pathlib.Path:
        return self.directory / f"log-{gen:08d}.bin"

    def _snap_path(self, gen: int) -> pathlib.Path:
        return self.directory / f"snap-{gen:08d}.bin"

    def _open_log(self, gen: int, *, fresh: bool) -> None:
        """Open ``log-<gen>`` for appending (caller holds ``_lock`` or is
        ``__init__``). An existing log is truncated to its committed
        prefix first — appending after a torn tail would hide every
        record that follows it from recovery."""
        path = self._log_path(gen)
        if not fresh and path.exists():
            _, torn, keep = read_records(path)
            if torn:
                with path.open("r+b") as f:
                    f.truncate(keep)
                self.torn_truncated += 1
            f = path.open("ab", buffering=0)
            if keep == 0:                  # empty/garbage file: re-magic
                f.write(JOURNAL_MAGIC)
            self._log_bytes = max(keep, len(JOURNAL_MAGIC))
        else:
            f = path.open("wb", buffering=0)
            f.write(JOURNAL_MAGIC)
            self._log_bytes = len(JOURNAL_MAGIC)
        self._file = f

    #: a pending buffer past this size is flushed inline by ``append``
    #: (bounds group-commit memory under a burst with no ack boundary)
    FLUSH_BYTES = 1 << 20

    # -- append path ----------------------------------------------------------
    def append(self, op: str, header: Optional[Dict] = None,
               body: bytes = b"") -> None:
        """Append one record to the pending group-commit buffer.

        Records hit the file (page cache — the durability domain, see
        module docstring) at the next :meth:`flush`, which callers issue
        at every COMMIT POINT: before a wire reply or stream ack leaves
        the server, and after a journaled pop hands items to a local
        consumer. Between commit points nothing external depends on the
        buffered records — a crash loses only frames whose ack never
        left (the producer replays them) — so a windowed-ack stream
        pays one ``write(2)`` per ack batch, not per frame."""
        rec = _record_bytes(op, header, body)
        with self._lock:
            if self._file is None:
                return                     # closed — shutdown race, drop
            self._pending += rec
            self._log_bytes += len(rec)
            self.records_appended += 1
            if len(self._pending) >= self.FLUSH_BYTES:
                self._flush_locked()

    def _flush_locked(self) -> None:
        if self._pending and self._file is not None:
            self._file.write(self._pending)
            self._pending = bytearray()
            self.flushes += 1

    def flush(self) -> None:
        """Write the pending buffer: the group-commit boundary."""
        with self._lock:
            self._flush_locked()

    def note_publish(self, params: Any, version: int) -> None:
        """Journal a weight-store publish (the store's ``on_publish``
        hook): the encoded blob is both the journal body and the cached
        newest-version state a snapshot captures."""
        blob = encode_pytree(params)
        with self._pub_lock:
            cur = self._last_publish
            if cur is None or version >= cur[0]:
                self._last_publish = (int(version), blob)
        self.append("publish", {"version": int(version)}, blob)
        self.flush()                       # publishes are rare commit points

    def attach_store(self, store) -> None:
        """Install :meth:`note_publish` as ``store.on_publish``."""
        store.on_publish = self.note_publish

    # -- channel registration -------------------------------------------------
    def wrap(self, name: str, inner) -> "JournaledChannel":
        """Wrap ``inner`` (a FIFO-style channel) so every accepted put
        and every pop is journaled under ``name``."""
        chan = JournaledChannel(inner, self, name)
        self._channels[name] = chan
        return chan

    # -- size / compaction ----------------------------------------------------
    @property
    def log_bytes(self) -> int:
        with self._lock:
            return self._log_bytes

    def should_compact(self) -> bool:
        return not self.closed and self.log_bytes >= self.compact_bytes

    def compact(self, extra_records_fn: Optional[
            Callable[[], Iterable[Tuple[str, Dict, bytes]]]] = None) -> int:
        """Rotate the log and write a snapshot of current state (channel
        contents under their wrapper locks; stream/store records from
        ``extra_records_fn``, captured post-rotation — idempotent, see
        module docstring). Returns the new generation."""
        with self._compact_lock:
            chans = sorted(self._channels.items())
            for _, c in chans:
                c.journal_lock.acquire()
            try:
                with self._lock:
                    if self._file is None:
                        return self.gen
                    self._flush_locked()
                    self.gen += 1
                    gen = self.gen
                    self._file.close()
                    self._open_log(gen, fresh=True)
                records: List[Tuple[str, Dict, bytes]] = []
                for name, c in chans:
                    records.append(("chan_meta",
                                    {"chan": name, "capacity": c.capacity,
                                     "policy": c.policy}, b""))
                    items = c.peek_all()
                    if items:
                        records.append(("put",
                                        {"chan": name, "count": len(items)},
                                        encode_pytree(items)))
            finally:
                for _, c in chans:
                    c.journal_lock.release()
            if extra_records_fn is not None:
                records.extend(extra_records_fn())
            with self._pub_lock:
                lp = self._last_publish
            if lp is not None:
                records.append(("publish", {"version": lp[0]}, lp[1]))
            tmp = self._snap_path(gen).with_suffix(".tmp")
            with tmp.open("wb") as f:
                f.write(JOURNAL_MAGIC)
                for op, hdr, body in records:
                    f.write(_record_bytes(op, hdr, body))
                f.write(_record_bytes("snap_end", {}))
                f.flush()
                os.fsync(f.fileno())
            tmp.rename(self._snap_path(gen))
            # only after the rename is the old chain redundant
            for p in list(self.directory.iterdir()):
                m = _GEN_RE.match(p.name)
                if m and int(m.group(2)) < gen:
                    try:
                        p.unlink()
                    except OSError:
                        pass
            self.compactions += 1
            return gen

    def stats(self) -> Dict[str, float]:
        return {"journal_gen": float(self.gen),
                "journal_log_bytes": float(self.log_bytes),
                "journal_records": float(self.records_appended),
                "journal_flushes": float(self.flushes),
                "journal_compactions": float(self.compactions),
                "journal_torn_truncated": float(self.torn_truncated)}

    def close(self) -> None:
        with self._lock:
            self.closed = True
            if self._file is not None:
                self._flush_locked()
                self._file.close()
                self._file = None


# ---------------------------------------------------------------------------
# the journaled channel wrapper
# ---------------------------------------------------------------------------

class JournaledChannel:
    """Wraps a FIFO-style channel so {mutate, journal} is atomic.

    Blocking surface ops (``pop_batch``/``pop_many`` with a timeout) are
    re-expressed as polling loops of non-blocking inner ops, so the
    wrapper lock is never held across a wait — a blocked consumer can
    never deadlock a producer (or a compaction) out of the lock.

    The ``block`` backpressure policy is rejected at wrap time: its puts
    park *inside* the inner buffer waiting for pops, which cannot be made
    atomic with the journal append without serializing producers against
    consumers. The journaled channels this PR targets (the experience
    plane) default to ``drop_oldest``.
    """

    #: poll granularity for the blocking pop surface
    POLL_S = 0.002

    def __init__(self, inner, journal: TransportJournal, name: str):
        if getattr(inner, "policy", None) == "block":
            raise ValueError(
                "JournaledChannel does not support the 'block' "
                "backpressure policy (its puts wait inside the buffer; "
                "journal atomicity would serialize producers against "
                "consumers) — use drop_oldest/drop_newest")
        if not hasattr(inner, "peek_all"):
            raise TypeError(f"{type(inner).__name__} has no peek_all(); "
                            f"snapshots need a non-destructive capture")
        self.inner = inner
        self.journal = journal
        self.name = name
        # RLock: compact() holds it while calling peek_all()
        self.journal_lock = threading.RLock()
        journal.append("chan_meta", {"chan": name,
                                     "capacity": self.capacity,
                                     "policy": self.policy})

    # -- metadata delegation --------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(getattr(self.inner, "capacity", 0))

    @property
    def policy(self) -> str:
        return str(getattr(self.inner, "policy", "drop_oldest"))

    @property
    def total_pushed(self) -> int:
        return int(getattr(self.inner, "total_pushed", 0))

    @property
    def total_dropped(self) -> int:
        return int(getattr(self.inner, "total_dropped", 0))

    # -- producer surface -----------------------------------------------------
    def put(self, item: Any) -> bool:
        return self.put_many([item])[0]

    def put_many(self, items: List[Any], *,
                 encoded: Optional[bytes] = None,
                 stream_meta: Optional[Dict] = None) -> List[bool]:
        """Apply-then-append under the wrapper lock. ``encoded`` is the
        already-encoded blob of ``items`` when the caller has one (the
        server's put path received it on the wire) — reused verbatim iff
        every item was accepted, so the streaming hot path never pays a
        second encode. ``stream_meta`` (``{"stream", "seq", "window",
        "ack_every"}``) fuses the flush's dedup watermark into the SAME
        record — one append per streamed frame, and a recovered server
        can never hold the items without the watermark that dedups
        their replay (the verdicts are filled in here)."""
        items = list(items)
        if not items:
            return []
        with self.journal_lock:
            verdicts = [bool(v) for v in self.inner.put_many(items)]
            accepted = [it for it, v in zip(items, verdicts) if v]
            if accepted or stream_meta is not None:
                hdr = {"chan": self.name, "count": len(accepted)}
                if stream_meta is not None:
                    hdr.update(stream_meta)
                    hdr["verdicts"] = verdicts
                blob = b"" if not accepted else (
                    encoded if encoded is not None and all(verdicts)
                    else encode_pytree(accepted))
                self.journal.append("put", hdr, blob)
        return verdicts

    def put_many_encoded(self, items: List[Any], body: bytes,
                         stream_meta: Optional[Dict] = None) -> List[bool]:
        """The server dispatch's entry: items + their wire encoding."""
        return self.put_many(items, encoded=body, stream_meta=stream_meta)

    # -- consumer surface -----------------------------------------------------
    def _journaled_take(self, take: Callable[[], Optional[List[Any]]],
                        timeout: Optional[float]) -> Optional[List[Any]]:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            with self.journal_lock:
                got = take()
                if got:
                    self.journal.append("pop", {"chan": self.name,
                                                "n": len(got)})
                    # handing items to a local consumer is a commit
                    # point: flush so a crash cannot resurrect them
                    # (pops are coalesced, so this write is rare)
                    self.journal.flush()
                    return got
            if deadline is not None and time.monotonic() >= deadline:
                return None
            time.sleep(self.POLL_S)

    def pop_batch(self, n: int, timeout: Optional[float] = None
                  ) -> Optional[List[Any]]:
        return self._journaled_take(
            lambda: self.inner.pop_batch(n, timeout=0), timeout)

    def pop_many(self, max_items: int, timeout: Optional[float] = None
                 ) -> Optional[List[Any]]:
        return self._journaled_take(
            lambda: self.inner.pop_many(max_items, timeout=0), timeout)

    def drain(self) -> List[Any]:
        with self.journal_lock:
            got = self.inner.drain()
            if got:
                self.journal.append("pop", {"chan": self.name,
                                            "n": len(got)})
                self.journal.flush()
            return got

    # -- snapshot/restore -----------------------------------------------------
    def peek_all(self) -> List[Any]:
        with self.journal_lock:
            return self.inner.peek_all()

    def restore(self, items: List[Any]) -> int:
        """Refill the inner channel WITHOUT journaling: the items came
        *from* the journal, so they are already represented in the chain
        recovery replays."""
        accepted = 0
        for item in items:
            accepted += bool(self.inner.put(item))
        return accepted

    # -- passthrough ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.inner)

    def stats(self) -> Dict[str, float]:
        out = dict(self.inner.stats())
        out["journaled"] = 1.0
        return out


# ---------------------------------------------------------------------------
# recovery
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RecoveredState:
    """What a journal chain replays to: channel contents, stream dedup
    watermarks, and the newest weight-store version."""

    channels: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    streams: Dict[Tuple[str, str], Dict] = dataclasses.field(
        default_factory=dict)
    store: Optional[Tuple[int, bytes]] = None
    base_gen: int = 0
    records: int = 0
    torn_tail: bool = False
    puts: int = 0
    pops: int = 0
    items_in: int = 0
    items_out: int = 0

    def channel_items(self, name: str) -> List[Any]:
        return self.channels.get(name, {}).get("items", [])

    def store_params(self) -> Optional[Tuple[Any, int]]:
        if self.store is None:
            return None
        version, blob = self.store
        return decode_pytree(blob, copy=True), version


def _chan_entry(state: RecoveredState, name: str) -> Dict:
    return state.channels.setdefault(
        name, {"capacity": 0, "policy": "drop_oldest", "items": []})


def _stream_entry(state: RecoveredState, chan: str, stream: str) -> Dict:
    return state.streams.setdefault(
        (chan, stream), {"last_seq": -1, "acks": {}, "window": 32,
                         "ack_every": 1})


def _apply_stream_hdr(state: RecoveredState, hdr: Dict) -> None:
    """Fold one watermark header (a ``stream`` record, or the fused keys
    of a streamed ``put``) into the stream state — idempotent, max-seq."""
    s = _stream_entry(state, hdr["chan"], hdr["stream"])
    s["window"] = int(hdr.get("window", s["window"]))
    s["ack_every"] = int(hdr.get("ack_every", s["ack_every"]))
    seq = int(hdr["seq"])
    if seq > s["last_seq"]:
        s["last_seq"] = seq
    s["acks"][seq] = [bool(v) for v in hdr.get("verdicts", ())]
    keep = max(4 * s["window"], 64)
    while len(s["acks"]) > keep:
        del s["acks"][min(s["acks"])]


def _apply_record(state: RecoveredState, hdr: Dict, body: bytes) -> None:
    op = hdr.get("op")
    if op == "chan_meta":
        e = _chan_entry(state, hdr["chan"])
        e["capacity"] = int(hdr.get("capacity", 0))
        e["policy"] = str(hdr.get("policy", "drop_oldest"))
    elif op == "put":
        e = _chan_entry(state, hdr["chan"])
        if body:
            items = decode_pytree(body, copy=True)
            e["items"].extend(items)
            state.puts += 1
            state.items_in += len(items)
            cap = e["capacity"]
            if (cap and e["policy"] == "drop_oldest"
                    and len(e["items"]) > cap):
                del e["items"][:len(e["items"]) - cap]
        if "stream" in hdr:                # fused watermark (one record
            _apply_stream_hdr(state, hdr)  # per streamed frame)
    elif op == "pop":
        e = _chan_entry(state, hdr["chan"])
        n = int(hdr["n"])
        del e["items"][:n]
        state.pops += 1
        state.items_out += n
    elif op == "stream":
        _apply_stream_hdr(state, hdr)
    elif op == "stream_snap":
        s = _stream_entry(state, hdr["chan"], hdr["stream"])
        s["window"] = int(hdr.get("window", s["window"]))
        s["ack_every"] = int(hdr.get("ack_every", s["ack_every"]))
        seq = int(hdr.get("seq", -1))
        if seq > s["last_seq"]:
            s["last_seq"] = seq
        for k, v in hdr.get("acks", {}).items():
            s["acks"][int(k)] = [bool(x) for x in v]
        keep = max(4 * s["window"], 64)
        while len(s["acks"]) > keep:
            del s["acks"][min(s["acks"])]
    elif op == "publish":
        version = int(hdr["version"])
        if state.store is None or version >= state.store[0]:
            state.store = (version, body)
    elif op == "snap_end":
        pass
    state.records += 1


def recover(directory) -> RecoveredState:
    """Replay the newest valid snapshot + every log generation from it
    on: the state a replacement server resumes with. A torn final log
    record ends the committed prefix (flagged in ``torn_tail``); an
    interrupted (marker-less) snapshot is skipped in favor of the
    previous chain, whose logs are only deleted after a snapshot rename.
    """
    directory = pathlib.Path(directory)
    state = RecoveredState()
    gens = _scan_generations(directory)
    base = 0
    for g in reversed(gens["snap"]):
        records, torn, _ = read_records(directory / f"snap-{g:08d}.bin")
        if torn or not records or records[-1][0].get("op") != "snap_end":
            continue                       # interrupted compaction
        for hdr, body in records:
            _apply_record(state, hdr, body)
        base = g
        break
    state.base_gen = base
    for g in gens["log"]:
        if g < base:
            continue
        records, torn, _ = read_records(directory / f"log-{g:08d}.bin")
        for hdr, body in records:
            _apply_record(state, hdr, body)
        state.torn_tail = state.torn_tail or torn
    return state
