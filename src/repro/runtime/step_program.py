"""Train-step program IR: one optimizer step as a graph of named stages.

The single source of truth for *what one training step is*, consumed by
three executors that must never drift apart:

  * the fused single-mesh path (``StepProgram.fused`` → one jit, the
    default ``TrainerWorker`` step — byte-identical to the historical
    ``core.train_step.make_train_step``);
  * the pipelined executor (``runtime/pipeline_exec.py``) — jits each
    device stage separately and drives them from a static per-submesh
    RUN/SEND/RECV/FREE instruction schedule;
  * the sync/async schedulers, which only ever see
    ``TrainerWorker.train_on_batch`` and therefore inherit whichever of
    the two executors the config selected.

A stage is a named function with declared dataflow (``inputs`` →
``outputs`` buffer names) and, when a mesh is supplied, declared
PartitionSpec shardings for its pinned buffers. Stage *functions* come
from ``core.train_step`` — the fused path composes the very same
callables under ``jax.lax.scan``, so pipelined-vs-fused parity is
structural rather than asserted after the fact.

Step layout (paper §3.1 / App. C):

    collate(host) → fwd_bwd(×K micro) → grad_reduce(×K) →
        optim_update → publish(host)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

from repro.configs.base import ModelConfig, RLConfig


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One named stage of the step program.

    ``fn`` is the stage body (None for host-side stages the runtime owns,
    e.g. publish). ``init`` optionally builds the stage's carried
    accumulator (grad_reduce). ``per_micro`` stages run once per
    micro-batch inside a gradient-accumulation window. ``specs`` maps
    buffer names to PartitionSpec trees — the declared shardings the
    executor places those buffers under when a mesh is in play.
    """

    name: str
    inputs: Tuple[str, ...]
    outputs: Tuple[str, ...]
    fn: Optional[Callable] = None
    init: Optional[Callable] = None
    kind: str = "device"                 # {"device", "host"}
    per_micro: bool = False
    specs: Optional[Dict[str, object]] = None


@dataclasses.dataclass(frozen=True)
class StepProgram:
    """Validated sequence of stages + the fused whole-step function."""

    name: str
    stages: Tuple[StageSpec, ...]
    inputs: Tuple[str, ...] = ()         # externally-fed buffer names
    fused_fn: Optional[Callable] = None
    n_micro: int = 1

    def __post_init__(self):
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names in {names}")
        live = set(self.inputs)
        for s in self.stages:
            missing = [b for b in s.inputs if b not in live]
            if missing:
                raise ValueError(
                    f"stage {s.name!r} reads {missing} before any stage "
                    f"produces them (live: {sorted(live)})")
            live.update(s.outputs)

    def stage(self, name: str) -> StageSpec:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"{self.name!r} has no stage {name!r}; have "
                       f"{[s.name for s in self.stages]}")

    def fused(self, *, donate: bool = False):
        """The whole step as one jit — the single-mesh default path."""
        import jax
        if self.fused_fn is None:
            raise ValueError(f"program {self.name!r} has no fused form")
        return jax.jit(self.fused_fn,
                       donate_argnums=(0,) if donate else ())

    def describe(self) -> str:
        lines = [f"program {self.name} (K={self.n_micro}; "
                 f"feeds: {', '.join(self.inputs)})"]
        for s in self.stages:
            micro = f" ×{self.n_micro}" if s.per_micro else ""
            lines.append(
                f"  {s.name:<14}[{s.kind}]{micro:<4} "
                f"({', '.join(s.inputs)}) -> ({', '.join(s.outputs)})")
        return "\n".join(lines)


def _train_state_specs(cfg: ModelConfig, mesh):
    """Declared shardings for the TrainState buffer: params under the
    TP/FSDP rules, f32 Adam moments additionally ZeRO-sharded over
    ``data`` (optim/zero.py), scalars replicated."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.models.policy import init_policy_params
    from repro.optim import zero
    from repro.sharding import rules

    shapes = jax.eval_shape(functools.partial(init_policy_params, cfg),
                            jax.random.PRNGKey(0))
    pspec = rules.param_specs(cfg, shapes, mesh)
    mspec = zero.shard_moments_spec(shapes, pspec, data_axis="data",
                                    data_size=mesh.shape.get("data", 1))
    return {"params": pspec, "moments": mspec, "scalars": P()}


def build_train_step_program(cfg: ModelConfig, rl: RLConfig, *,
                             remat: bool = False, n_micro: int = 0,
                             mesh=None) -> StepProgram:
    """The GIPO train step as a StepProgram.

    Buffer conventions (what the executor's schedule names refer to):
      * ``state``   — TrainState (params frozen across the window, eq. 7)
      * ``micro``   — one contiguous micro-batch slice (App. C.1)
      * ``grads``   — one micro-batch's grads (FREEd after folding)
      * ``aux``     — (metrics, packed adv stats) from that micro-batch
      * ``acc``     — (f32 grad accumulator, stats accumulator)
    """
    import jax.numpy as jnp

    # NB: repro.core's __init__ rebinds the attribute ``train_step`` to
    # the function, shadowing the submodule for plain imports
    import importlib
    core = importlib.import_module("repro.core.train_step")

    n_micro = n_micro or rl.grad_accum
    specs = _train_state_specs(cfg, mesh) if mesh is not None else None

    def fwd_bwd(state, micro):
        return core.microbatch_grads(state.params, micro, state.adv_norm,
                                     cfg=cfg, rl=rl, remat=remat)

    def grad_init(state):
        return (core.zero_grads_like(state.params), jnp.zeros((3,)))

    def grad_reduce(acc, grads, aux):
        grads_acc, stats_acc = core.accumulate_grads(
            acc[0], grads, acc[1], aux[1], n_micro)
        return (grads_acc, stats_acc)

    def optim_update(state, acc, aux):
        return core.apply_update(state, acc[0], acc[1], aux[0], rl=rl)

    def fused(state, batch):
        return core.train_step(state, batch, cfg=cfg, rl=rl, remat=remat)

    from repro.runtime.trainer import collate_segments
    stages = (
        StageSpec("collate", inputs=("segments",), outputs=("batch",),
                  fn=collate_segments, kind="host"),
        StageSpec("fwd_bwd", inputs=("state", "micro"),
                  outputs=("grads", "aux"), fn=fwd_bwd, per_micro=True),
        StageSpec("grad_reduce", inputs=("acc", "grads", "aux"),
                  outputs=("acc",), fn=grad_reduce, init=grad_init,
                  per_micro=True),
        StageSpec("optim_update", inputs=("state", "acc", "aux"),
                  outputs=("state", "metrics"), fn=optim_update,
                  specs={"state": specs} if specs else None),
        StageSpec("publish", inputs=("state",), outputs=(), kind="host"),
    )
    return StepProgram(name="gipo_train_step", stages=stages,
                       inputs=("segments", "state", "micro", "acc"),
                       fused_fn=fused, n_micro=n_micro)
