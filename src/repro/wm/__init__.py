"""The plug-and-play pixel-interface world model (paper §4).

``M_obs`` is a DIAMOND-style EDM diffusion next-frame predictor; ``M_reward``
is a success-probability classifier; ``imagination`` runs the horizon-H
alternating rollout with potential-based rewards (eq. 4); ``wm_system``
attaches them onto the asynchronous pipeline's service bus
(``system.attach(WorldModelAttachment(...))`` — no orchestrator subclass)
with the three decoupled trainer loops of §4.2."""
from repro.wm.denoiser import (  # noqa: F401
    denoiser_init,
    denoiser_apply,
    denoiser_loss,
    sample_next_frame,
)
from repro.wm.reward import (  # noqa: F401
    reward_init,
    reward_apply,
    reward_loss,
)
from repro.wm.imagination import ImaginationWorker, imagine_segment  # noqa: F401
from repro.wm.wm_system import (  # noqa: F401
    AcceRLWMSystem,
    WorldModelAttachment,
    WorldModelTrainer,
)
