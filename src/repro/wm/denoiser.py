"""M_obs — the observation model: an EDM-preconditioned diffusion
next-frame predictor (DIAMOND-style, arXiv:2210.xxxxx EDM parameterization
as used by arXiv:2405.12399).

The paper's WM operates on 128×128 RGB frames; per the hardware-adaptation
note (DESIGN.md §2) the pixel *interface* is preserved but the denoiser
consumes the frame vector directly (the conv codec is the allowed stubbed
modality frontend). Conditioning = the last ``history_frames`` frames +
the current action-token chunk, exactly the paper's
"historical observation sequences and current action chunks".

All functions are pure (init, apply) pairs over dict pytrees, jit/shard-safe.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import WMConfig
from repro.models.layers import Params, dense_init

SIGMA_MIN = 2e-3
SIGMA_MAX = 80.0
RHO = 7.0
P_MEAN = -1.2
P_STD = 1.2


# ---------------------------------------------------------------------------
# Network: MLP denoiser F(c_in·x, cond, c_noise)
# ---------------------------------------------------------------------------

def denoiser_init(key, frame_dim: int, action_dim: int, action_vocab: int,
                  cfg: WMConfig) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d = cfg.denoiser_d_model
    cond_dim = cfg.history_frames * frame_dim + action_dim * 8 + 1
    return {
        "act_emb": dense_init(k1, (action_vocab, 8), jnp.float32, scale=1.0),
        "w_in": dense_init(k2, (frame_dim + cond_dim, d), jnp.float32),
        "b_in": jnp.zeros((d,), jnp.float32),
        "w_h": dense_init(k3, (d, d), jnp.float32),
        "b_h": jnp.zeros((d,), jnp.float32),
        "w_h2": dense_init(k4, (d, d), jnp.float32),
        "b_h2": jnp.zeros((d,), jnp.float32),
        "w_out": dense_init(k5, (d, frame_dim), jnp.float32),
        "b_out": jnp.zeros((frame_dim,), jnp.float32),
    }


def _network(params: Params, x_in: jnp.ndarray, history: jnp.ndarray,
             actions: jnp.ndarray, c_noise: jnp.ndarray) -> jnp.ndarray:
    """x_in: [B, F] (pre-scaled); history: [B, H, F]; actions: [B, A] i32;
    c_noise: [B]."""
    b = x_in.shape[0]
    a_emb = jnp.take(params["act_emb"], actions, axis=0).reshape(b, -1)
    h = jnp.concatenate(
        [x_in, history.reshape(b, -1), a_emb, c_noise[:, None]], axis=-1)
    h = jax.nn.silu(h @ params["w_in"] + params["b_in"])
    h = h + jax.nn.silu(h @ params["w_h"] + params["b_h"])
    h = h + jax.nn.silu(h @ params["w_h2"] + params["b_h2"])
    return h @ params["w_out"] + params["b_out"]


# ---------------------------------------------------------------------------
# EDM preconditioning
# ---------------------------------------------------------------------------

def denoiser_apply(params: Params, x_noisy: jnp.ndarray, sigma: jnp.ndarray,
                   history: jnp.ndarray, actions: jnp.ndarray,
                   sigma_data: float) -> jnp.ndarray:
    """D_θ(x; σ) = c_skip·x + c_out·F(c_in·x, cond, c_noise)."""
    sd2 = sigma_data ** 2
    s2 = jnp.square(sigma)
    c_skip = sd2 / (s2 + sd2)
    c_out = sigma * sigma_data / jnp.sqrt(s2 + sd2)
    c_in = 1.0 / jnp.sqrt(s2 + sd2)
    c_noise = jnp.log(sigma) / 4.0
    f = _network(params, c_in[:, None] * x_noisy, history, actions, c_noise)
    return c_skip[:, None] * x_noisy + c_out[:, None] * f


def denoiser_loss(params: Params, key, frames_next: jnp.ndarray,
                  history: jnp.ndarray, actions: jnp.ndarray,
                  cfg: WMConfig) -> jnp.ndarray:
    """EDM training objective with λ(σ) weighting."""
    b = frames_next.shape[0]
    k1, k2 = jax.random.split(key)
    log_sigma = P_MEAN + P_STD * jax.random.normal(k1, (b,))
    sigma = jnp.exp(log_sigma)
    noise = jax.random.normal(k2, frames_next.shape) * sigma[:, None]
    d = denoiser_apply(params, frames_next + noise, sigma, history, actions,
                       cfg.sigma_data)
    sd2 = cfg.sigma_data ** 2
    lam = (jnp.square(sigma) + sd2) / jnp.square(sigma * cfg.sigma_data)
    return jnp.mean(lam * jnp.mean(jnp.square(d - frames_next), axis=-1))


# ---------------------------------------------------------------------------
# Sampling (Euler over the Karras σ-schedule)
# ---------------------------------------------------------------------------

def karras_schedule(n: int) -> jnp.ndarray:
    i = jnp.arange(n, dtype=jnp.float32)
    s = (SIGMA_MAX ** (1 / RHO)
         + i / max(n - 1, 1) * (SIGMA_MIN ** (1 / RHO)
                                - SIGMA_MAX ** (1 / RHO))) ** RHO
    return jnp.concatenate([s, jnp.zeros((1,))])


def sample_next_frame(params: Params, key, history: jnp.ndarray,
                      actions: jnp.ndarray, cfg: WMConfig) -> jnp.ndarray:
    """Generate ô_{t+1} given history and the action chunk."""
    b, _, f = history.shape
    sigmas = karras_schedule(cfg.diffusion_steps)
    x = jax.random.normal(key, (b, f)) * sigmas[0]

    def body(x, i):
        s_cur, s_next = sigmas[i], sigmas[i + 1]
        denoised = denoiser_apply(params, x, jnp.full((b,), s_cur),
                                  history, actions, cfg.sigma_data)
        d = (x - denoised) / s_cur
        return x + (s_next - s_cur) * d, None

    x, _ = jax.lax.scan(body, x, jnp.arange(cfg.diffusion_steps))
    return x


def make_denoiser_train_step(cfg: WMConfig, lr: float = 1e-4):
    from repro.optim import adamw

    def step(params, opt, key, frames_next, history, actions):
        loss, grads = jax.value_and_grad(denoiser_loss)(
            params, key, frames_next, history, actions, cfg)
        new_params, new_opt, _ = adamw.update(grads, opt, params,
                                              jnp.asarray(lr))
        return new_params, new_opt, loss
    return jax.jit(step)


def make_sampler(cfg: WMConfig):
    return jax.jit(lambda params, key, history, actions:
                   sample_next_frame(params, key, history, actions, cfg))
