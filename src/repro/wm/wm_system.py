"""AcceRL-WM: the world-model-augmented mode (paper §4, Fig. 2b).

Extends the asynchronous pipeline with:
  * B_wm — real transitions feeding WM training (collected by the same
    rollout workers via the alternating strategy),
  * B_img — imagined τ̂ segments from :class:`ImaginationWorker`s,
  * three decoupled trainer loops (§4.2): M_policy continuously on B_img;
    M_obs every ``obs_train_interval`` cycles on B_wm; M_reward every
    ``reward_train_interval`` steps on B_wm,
  * ``pretrain_world_model`` — the paper's offline WM pre-training on
    oracle trajectories (1,000 offline trajectories in Fig. 4b).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig, WMConfig
from repro.data.replay import FIFOReplayBuffer, RingReplayBuffer
from repro.envs.toy_manipulation import FRAME_DIM, ManipulationEnv
from repro.optim import adamw
from repro.runtime.orchestrator import AcceRLSystem
from repro.runtime.trainer import TrainerWorker
from repro.wm import denoiser as dn
from repro.wm import reward as rw
from repro.wm.imagination import ImaginationWorker


def pretrain_world_model(suite: str, wm: WMConfig, *, trajectories: int = 100,
                         train_steps: int = 300, batch: int = 64,
                         action_vocab: int = 64, action_dim: int = 7,
                         max_steps: int = 30, seed: int = 0) -> Dict:
    """Collect oracle (out-of-distribution) trajectories offline and
    pre-train M_obs + M_reward — the paper's 1,000-trajectory setup."""
    env = ManipulationEnv(suite=suite, action_vocab=action_vocab,
                          action_dim=action_dim, max_steps=max_steps,
                          seed=seed)
    transitions = []
    rng = np.random.default_rng(seed)
    for ep in range(trajectories):
        obs = env.reset(int(rng.integers(0, 10)))
        done = False
        frames, actions, successes = [obs["frame"]], [], []
        while not done:
            a = env.oracle_action()
            obs, r, done, info = env.step(a)
            frames.append(obs["frame"])
            actions.append(a)
            successes.append(float(info["success"]))
        for i in range(len(actions)):
            transitions.append((frames[i], actions[i], frames[i + 1],
                                successes[i]))
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    obs_params = dn.denoiser_init(k1, FRAME_DIM, action_dim, action_vocab,
                                  wm)
    rew_params = rw.reward_init(k2, FRAME_DIM)
    obs_opt = adamw.init(obs_params)
    rew_opt = adamw.init(rew_params)
    dn_step = dn.make_denoiser_train_step(wm)
    rw_step = rw.make_reward_train_step()

    n = len(transitions)
    f0 = np.stack([t[0] for t in transitions])
    ac = np.stack([t[1] for t in transitions])
    f1 = np.stack([t[2] for t in transitions])
    sc = np.array([t[3] for t in transitions], np.float32)
    losses = {"obs": [], "reward": []}
    for step in range(train_steps):
        idx = rng.integers(0, n, batch)
        hist = np.repeat(f0[idx][:, None], wm.history_frames, axis=1)
        k3, sub = jax.random.split(k3)
        obs_params, obs_opt, l_obs = dn_step(obs_params, obs_opt, sub,
                                             f1[idx], hist, ac[idx])
        rew_params, rew_opt, l_rew = rw_step(rew_params, rew_opt, f1[idx],
                                             sc[idx])
        losses["obs"].append(float(l_obs))
        losses["reward"].append(float(l_rew))
    return {"obs": obs_params, "reward": rew_params,
            "obs_opt": obs_opt, "reward_opt": rew_opt,
            "losses": losses, "transitions": n}


class AcceRLWMSystem(AcceRLSystem):
    """World-model-augmented asynchronous system."""

    def __init__(self, cfg: ModelConfig, rl: RLConfig, rt: RuntimeConfig,
                 wm: WMConfig, *, wm_params: Optional[Dict] = None,
                 num_imagination_workers: int = 1,
                 imagination_batch: int = 16, seed: int = 0, **kw):
        super().__init__(cfg, rl, rt, collect_frames=True, seed=seed, **kw)
        self.wm = wm
        self.img_buffer = FIFOReplayBuffer(rt.img_replay_capacity)
        key = jax.random.PRNGKey(seed + 99)
        k1, k2 = jax.random.split(key)
        if wm_params is None:
            wm_params = {
                "obs": dn.denoiser_init(k1, FRAME_DIM, self.cfg.action_dim,
                                        self.cfg.action_vocab_size, wm),
                "reward": rw.reward_init(k2, FRAME_DIM),
            }
        # shared mutable reference — imagination workers read the newest
        # WM weights ("broadcast to the Inference Pool only on update")
        self.wm_params = {"obs": wm_params["obs"],
                          "reward": wm_params["reward"]}
        self._obs_opt = wm_params.get("obs_opt") or adamw.init(
            self.wm_params["obs"])
        self._rew_opt = wm_params.get("reward_opt") or adamw.init(
            self.wm_params["reward"])
        self._dn_step = dn.make_denoiser_train_step(wm)
        self._rw_step = rw.make_reward_train_step()
        # the WM-mode policy trainer consumes B_img
        self.img_trainer = TrainerWorker(self.cfg, rl, rt, self.img_buffer,
                                         self.store,
                                         batch_episodes=imagination_batch,
                                         seed=seed)
        self.imaginers = [
            ImaginationWorker(i, self.cfg, wm, self.store, self.wm_params,
                              self.frame_buffer, self.img_buffer,
                              batch=imagination_batch, seed=seed + i)
            for i in range(num_imagination_workers)
        ]
        self._wm_stop = threading.Event()
        self._wm_thread = threading.Thread(target=self._wm_train_loop,
                                           daemon=True, name="wm-trainer")
        self._key = jax.random.PRNGKey(seed + 1234)
        self.wm_updates = {"obs": 0, "reward": 0}

    # -- the M_obs / M_reward trainer loops (§4.2) ----------------------------
    def _wm_train_loop(self) -> None:
        cycle = 0
        while not self._wm_stop.is_set():
            batch = self.frame_buffer.sample(32)
            if batch is None:
                time.sleep(0.05)
                continue
            cycle += 1
            f1 = np.stack([b["next_frame"] for b in batch]).astype(np.float32)
            f0 = np.stack([b["frame"] for b in batch]).astype(np.float32)
            ac = np.stack([b["actions"] for b in batch])
            sc = np.array([b["success"] for b in batch], np.float32)
            if cycle % self.wm.obs_train_interval == 0:
                hist = np.repeat(f0[:, None], self.wm.history_frames, axis=1)
                self._key, sub = jax.random.split(self._key)
                self.wm_params["obs"], self._obs_opt, _ = self._dn_step(
                    self.wm_params["obs"], self._obs_opt, sub, f1, hist, ac)
                self.wm_updates["obs"] += 1
            if cycle % self.wm.reward_train_interval == 0:
                self.wm_params["reward"], self._rew_opt, _ = self._rw_step(
                    self.wm_params["reward"], self._rew_opt, f1, sc)
                self.wm_updates["reward"] += 1
            time.sleep(0.001)

    # -- run --------------------------------------------------------------------
    def run_wm(self, *, train_steps: int,
               wall_timeout_s: float = 300.0) -> Dict:
        """Alternating real rollout + imagination, three trainer loops."""
        t0 = time.monotonic()
        self.inference.start()
        self.img_trainer.start()
        self._wm_thread.start()
        for w in self.workers:
            w.start()
        for im in self.imaginers:
            im.start()
        try:
            while (self.img_trainer.steps_done < train_steps
                   and time.monotonic() - t0 < wall_timeout_s):
                time.sleep(0.02)
        finally:
            for w in self.workers:
                w.stop()
            for im in self.imaginers:
                im.stop()
            self._wm_stop.set()
            self.img_trainer.stop()
            self.inference.stop()
            for w in self.workers:
                w.join()
            for im in self.imaginers:
                im.join()
        m = self.metrics(time.monotonic() - t0)
        m["imagined_steps"] = sum(im.imagined_steps for im in self.imaginers)
        m["img_train_steps"] = self.img_trainer.steps_done
        m["wm_updates"] = dict(self.wm_updates)
        m["real_env_steps"] = m["env_steps"]
        return m
