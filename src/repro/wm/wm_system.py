"""AcceRL-WM: the world-model-augmented mode (paper §4, Fig. 2b).

The world model is a *plug-and-play attachment*, not a subclass of the
orchestrator: :class:`WorldModelAttachment` binds to a running-capable
:class:`~repro.runtime.orchestrator.AcceRLSystem` via ``system.attach(...)``
and registers on the service bus

  * B_img — a FIFO channel of imagined τ̂ segments,
  * N :class:`~repro.wm.imagination.ImaginationWorker` producer services,
  * a :class:`WorldModelTrainer` service running the decoupled M_obs /
    M_reward loops (§4.2: M_obs every ``obs_train_interval`` cycles on
    B_wm; M_reward every ``reward_train_interval``),
  * a rewire of the existing policy trainer onto a
    :class:`~repro.runtime.experience.MixedExperienceSource` over (B,
    B_img) at ``rt.mix_real_fraction`` (0.0 = the paper's pure-imagination
    diet) — the same trainer service, a different experience diet.

``AcceRLWMSystem(...)`` is the one-call constructor: it builds the base
system with frame collection on and attaches the world model — the
returned object IS an ``AcceRLSystem``; ``run_wm`` is the async scheduler
over the extended service set.

``pretrain_world_model`` — the paper's offline WM pre-training on oracle
trajectories (1,000 offline trajectories in Fig. 4b).
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, RLConfig, RuntimeConfig, WMConfig
from repro.data.prefetch import Prefetcher
from repro.envs.toy_manipulation import FRAME_DIM, ManipulationEnv
from repro.optim import adamw
from repro.runtime.experience import FifoChannel, MixedExperienceSource
from repro.runtime.orchestrator import AcceRLSystem
from repro.runtime.service import Service
from repro.runtime.trainer import TrainerWorker, collate_segments
from repro.wm import denoiser as dn
from repro.wm import reward as rw
from repro.wm.imagination import ImaginationWorker


def pretrain_world_model(suite: str, wm: WMConfig, *, trajectories: int = 100,
                         train_steps: int = 300, batch: int = 64,
                         action_vocab: int = 64, action_dim: int = 7,
                         max_steps: int = 30, seed: int = 0) -> Dict:
    """Collect oracle (out-of-distribution) trajectories offline and
    pre-train M_obs + M_reward — the paper's 1,000-trajectory setup."""
    env = ManipulationEnv(suite=suite, action_vocab=action_vocab,
                          action_dim=action_dim, max_steps=max_steps,
                          seed=seed)
    transitions = []
    rng = np.random.default_rng(seed)
    for ep in range(trajectories):
        obs = env.reset(int(rng.integers(0, 10)))
        done = False
        frames, actions, successes = [obs["frame"]], [], []
        while not done:
            a = env.oracle_action()
            obs, r, done, info = env.step(a)
            frames.append(obs["frame"])
            actions.append(a)
            successes.append(float(info["success"]))
        for i in range(len(actions)):
            transitions.append((frames[i], actions[i], frames[i + 1],
                                successes[i]))
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    obs_params = dn.denoiser_init(k1, FRAME_DIM, action_dim, action_vocab,
                                  wm)
    rew_params = rw.reward_init(k2, FRAME_DIM)
    obs_opt = adamw.init(obs_params)
    rew_opt = adamw.init(rew_params)
    dn_step = dn.make_denoiser_train_step(wm)
    rw_step = rw.make_reward_train_step()

    n = len(transitions)
    f0 = np.stack([t[0] for t in transitions])
    ac = np.stack([t[1] for t in transitions])
    f1 = np.stack([t[2] for t in transitions])
    sc = np.array([t[3] for t in transitions], np.float32)
    losses = {"obs": [], "reward": []}
    for step in range(train_steps):
        idx = rng.integers(0, n, batch)
        hist = np.repeat(f0[idx][:, None], wm.history_frames, axis=1)
        k3, sub = jax.random.split(k3)
        obs_params, obs_opt, l_obs = dn_step(obs_params, obs_opt, sub,
                                             f1[idx], hist, ac[idx])
        rew_params, rew_opt, l_rew = rw_step(rew_params, rew_opt, f1[idx],
                                             sc[idx])
        losses["obs"].append(float(l_obs))
        losses["reward"].append(float(l_rew))
    return {"obs": obs_params, "reward": rew_params,
            "obs_opt": obs_opt, "reward_opt": rew_opt,
            "losses": losses, "transitions": n}


class WorldModelTrainer(Service):
    """The M_obs / M_reward trainer loops (§4.2) as one bus service:
    samples real transitions from B_wm and updates the shared WM parameter
    reference in place ("broadcast to the Inference Pool only on update" —
    imagination workers read the same dict)."""

    def __init__(self, wm: WMConfig, wm_params: Dict, opts: Dict,
                 frame_channel, *, batch: int = 32, seed: int = 0,
                 driven: bool = False):
        super().__init__("wm-trainer", role="wm")
        self.wm = wm
        self.wm_params = wm_params            # shared mutable reference
        self._obs_opt = opts["obs"]
        self._rew_opt = opts["reward"]
        self._dn_step = dn.make_denoiser_train_step(wm)
        self._rw_step = rw.make_reward_train_step()
        self.frame_channel = frame_channel
        self.batch = batch
        self._key = jax.random.PRNGKey(seed + 1234)
        # driven=True: cycles come from an external driver (the pipeline
        # executor's WM stage) instead of this service's own loop
        self.driven = driven
        self._cycle = 0

    @property
    def updates(self) -> Dict[str, int]:
        return {"obs": int(self.metrics.counter("obs_updates")),
                "reward": int(self.metrics.counter("reward_updates"))}

    def sample_batch(self):
        """Next B_wm batch, or None when the channel is still empty —
        the pipeline executor's WM feed function."""
        return self.frame_channel.sample(self.batch)

    def train_cycle(self, batch) -> Dict[str, int]:
        """One decoupled M_obs / M_reward cycle on a sampled B_wm batch
        (§4.2) — the body of the free-running loop, and the pipeline
        executor's ``wm_update`` stage when driven."""
        self._cycle += 1
        cycle = self._cycle
        f1 = np.stack([b["next_frame"] for b in batch]).astype(np.float32)
        f0 = np.stack([b["frame"] for b in batch]).astype(np.float32)
        ac = np.stack([b["actions"] for b in batch])
        sc = np.array([b["success"] for b in batch], np.float32)
        with self.metrics.timer("busy_s"):
            if cycle % self.wm.obs_train_interval == 0:
                hist = np.repeat(f0[:, None], self.wm.history_frames,
                                 axis=1)
                self._key, sub = jax.random.split(self._key)
                self.wm_params["obs"], self._obs_opt, _ = self._dn_step(
                    self.wm_params["obs"], self._obs_opt, sub, f1, hist,
                    ac)
                self.metrics.inc("obs_updates")
            if cycle % self.wm.reward_train_interval == 0:
                self.wm_params["reward"], self._rew_opt, _ = \
                    self._rw_step(self.wm_params["reward"],
                                  self._rew_opt, f1, sc)
                self.metrics.inc("reward_updates")
        return {"cycle": cycle}

    def _run(self) -> None:
        while not self._stop.is_set():
            if self.driven:                     # pipeline-executor drive
                time.sleep(0.05)
                continue
            batch = self.sample_batch()
            if batch is None:
                time.sleep(0.05)
                continue
            self.train_cycle(batch)
            time.sleep(0.001)


class WorldModelAttachment:
    """Binds the world model onto a base system's service bus."""

    def __init__(self, wm: WMConfig, *, wm_params: Optional[Dict] = None,
                 num_imagination_workers: int = 1,
                 imagination_batch: int = 16, seed: int = 0):
        self.wm = wm
        self._init_params = wm_params
        self.num_imagination_workers = num_imagination_workers
        self.imagination_batch = imagination_batch
        self.seed = seed
        # populated by bind()
        self.img_channel: Optional[FifoChannel] = None
        self.wm_params: Optional[Dict] = None
        self.wm_trainer: Optional[WorldModelTrainer] = None
        self.imaginers: list = []
        self.img_trainer: Optional[TrainerWorker] = None

    def bind(self, system: AcceRLSystem) -> None:
        if system.frame_channel is None:
            raise RuntimeError(
                "world-model attachment needs real transitions: build the "
                "system with collect_frames=True (B_wm)")
        cfg, rl, rt = system.cfg, system.rl, system.rt
        if (0.0 < rt.mix_real_fraction < 1.0
                and system.segment_horizon != self.wm.imagine_horizon):
            # a mixed diet collates real and imagined segments into ONE
            # super-batch — their time axes must agree, or np.stack dies
            # deep inside the prefetcher thread instead of here
            raise ValueError(
                f"mix_real_fraction={rt.mix_real_fraction} blends real "
                f"segments (horizon {system.segment_horizon}) with "
                f"imagined ones (horizon {self.wm.imagine_horizon}) in one "
                f"batch; set segment_horizon == wm.imagine_horizon")
        seed = self.seed
        self.img_channel = FifoChannel(rt.img_replay_capacity,
                                       policy=rt.replay_backpressure)
        key = jax.random.PRNGKey(seed + 99)
        k1, k2 = jax.random.split(key)
        init = self._init_params or {}
        # shared mutable reference — imagination workers read the newest
        # WM weights without any copy or re-broadcast
        self.wm_params = {
            "obs": init.get("obs") if init.get("obs") is not None else
            dn.denoiser_init(k1, FRAME_DIM, cfg.action_dim,
                             cfg.action_vocab_size, self.wm),
            "reward": init.get("reward") if init.get("reward") is not None
            else rw.reward_init(k2, FRAME_DIM),
        }
        opts = {"obs": init.get("obs_opt") or adamw.init(
                    self.wm_params["obs"]),
                "reward": init.get("reward_opt") or adamw.init(
                    self.wm_params["reward"])}
        # rewire the SAME policy trainer to consume (B, B_img) at the
        # configured real/imagined mix — no second TrainerWorker, so the
        # params/optimizer tree and the train step are built exactly once
        source = MixedExperienceSource(
            system.experience, self.img_channel,
            real_fraction=rt.mix_real_fraction)
        trainer = system.trainer
        trainer.source = source
        trainer.prefetcher = Prefetcher(source, self.imagination_batch,
                                        collate_segments,
                                        depth=rt.prefetch_depth)
        self.img_trainer = trainer
        system.img_trainer = trainer

        # pipeline mode: the WM trainer becomes the second pipeline stage
        # on its own submesh — the executor drives train_cycle between
        # policy micro-batches instead of the service's own loop
        driven = rt.pipeline and getattr(trainer, "pipeline", None) is not None
        self.wm_trainer = system.registry.register(WorldModelTrainer(
            self.wm, self.wm_params, opts, system.frame_channel,
            seed=seed, driven=driven))
        if driven:
            trainer.set_wm_stage(self.wm_trainer.train_cycle,
                                 self.wm_trainer.sample_batch)
        self.imaginers = [
            system.registry.register(ImaginationWorker(
                i, cfg, self.wm, system.store, self.wm_params,
                system.frame_channel, self.img_channel,
                batch=self.imagination_batch, seed=seed + i))
            for i in range(self.num_imagination_workers)
        ]
        system.imaginers = self.imaginers
        system.wm_params = self.wm_params
        system.wm_trainer = self.wm_trainer

    def extend_metrics(self, m: Dict, system: AcceRLSystem) -> None:
        m["imagined_steps"] = sum(im.imagined_steps for im in self.imaginers)
        m["img_train_steps"] = self.img_trainer.steps_done
        m["wm_updates"] = self.wm_trainer.updates
        m["real_env_steps"] = m["env_steps"]
        m["img_buffer_dropped"] = self.img_channel.total_dropped
        m["mix_real_fraction"] = self.img_trainer.source.real_fraction


def AcceRLWMSystem(cfg: ModelConfig, rl: RLConfig, rt: RuntimeConfig,
                   wm: WMConfig, *, wm_params: Optional[Dict] = None,
                   num_imagination_workers: int = 1,
                   imagination_batch: int = 16, seed: int = 0,
                   **kw) -> AcceRLSystem:
    """World-model-augmented asynchronous system: the base
    :class:`AcceRLSystem` (collecting real frames into B_wm) with a
    :class:`WorldModelAttachment` plugged onto its service bus."""
    system = AcceRLSystem(cfg, rl, rt, collect_frames=True, seed=seed, **kw)
    system.attach(WorldModelAttachment(
        wm, wm_params=wm_params,
        num_imagination_workers=num_imagination_workers,
        imagination_batch=imagination_batch, seed=seed))
    return system
