"""Imagination rollouts (paper §4.1).

A real frame o_t seeds the rollout (ô_t = o_t); the policy M_policy produces
â_t; M_obs samples ô_{t+1}; M_reward scores both frames; the imagined
reward is the potential difference (eq. 4)

    r̂_t = M_reward(ô_{t+1}) − M_reward(ô_t)

scaled by ``reward_scale``, with the termination signal d̂one from the
success probability. Trajectories are STRICTLY capped at horizon H to bound
autoregressive compounding error, packaged per eq. 3, and pushed to B_img.

The whole horizon-H rollout is ONE jitted ``lax.scan`` program, so an
imagination worker generates a full τ̂ batch per device dispatch —
"completely bypassing the physical simulator's latency".
"""
from __future__ import annotations

import contextlib
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, WMConfig

# Import-gated tracing (see transport.faults for the idiom).
if os.environ.get("REPRO_TRACE"):
    from repro.runtime import telemetry as _tel
else:  # pragma: no cover - default path
    _tel = None

_NULL_CTX = contextlib.nullcontext()
from repro.models.policy import sample_action_sequence
from repro.models.transformer import FRONTEND_DIM
from repro.runtime.service import Service
from repro.wm import denoiser as dn
from repro.wm import reward as rw

SUCCESS_THRESHOLD = 0.9


def _frame_prefix(frames: jnp.ndarray) -> jnp.ndarray:
    """[B, F_env] -> [B, 1, FRONTEND_DIM] zero-padded stub embedding."""
    b, f = frames.shape
    pad = jnp.zeros((b, FRONTEND_DIM - f), frames.dtype)
    return jnp.concatenate([frames, pad], axis=-1)[:, None, :]


def imagine_rollout(policy_params, obs_params, reward_params, key,
                    tokens: jnp.ndarray, frame0: jnp.ndarray,
                    step0: jnp.ndarray, *, cfg: ModelConfig,
                    wm: WMConfig) -> Dict[str, jnp.ndarray]:
    """Horizon-H imagined rollout from real seed frames.

    tokens: [B, T_obs] (instruction — constant across the horizon);
    frame0: [B, F]; step0: [B]. Returns eq.-3 arrays with an H+1 slot.
    """
    b, f = frame0.shape
    h_frames = jnp.repeat(frame0[:, None, :], wm.history_frames, axis=1)
    p0 = rw.reward_apply(reward_params, frame0)

    def body(carry, key_t):
        frame, hist, step, p_cur = carry
        k_act, k_obs = jax.random.split(key_t)
        actions, logp, value = sample_action_sequence(
            cfg, policy_params, k_act, tokens, step, _frame_prefix(frame))
        frame_next = dn.sample_next_frame(obs_params, k_obs, hist, actions,
                                          wm)
        p_next = rw.reward_apply(reward_params, frame_next)
        reward = wm.reward_scale * (p_next - p_cur)          # eq. 4
        done = (p_next > SUCCESS_THRESHOLD).astype(jnp.float32)
        hist = jnp.concatenate([hist[:, 1:], frame_next[:, None]], axis=1)
        out = dict(frame=frame, actions=actions, logp=logp, value=value,
                   reward=reward, done=done, step=step)
        return (frame_next, hist, step + 1, p_next), out

    keys = jax.random.split(key, wm.imagine_horizon)
    (frame_h, _, step_h, _), outs = jax.lax.scan(
        body, (frame0, h_frames, step0, p0), keys)

    # [H, B, ...] -> [B, H, ...]; append the H+1 bootstrap slot
    tr = lambda x: jnp.moveaxis(x, 0, 1)
    frames = jnp.concatenate([tr(outs["frame"]), frame_h[:, None]], axis=1)
    steps = jnp.concatenate([tr(outs["step"]), step_h[:, None]], axis=1)
    zeros_a = jnp.zeros((b, 1) + outs["actions"].shape[2:],
                        outs["actions"].dtype)
    zeros_l = jnp.zeros((b, 1) + outs["logp"].shape[2:], jnp.float32)
    return {
        "frames": frames,                                     # [B, H+1, F]
        "obs_tokens": jnp.repeat(tokens[:, None], wm.imagine_horizon + 1,
                                 axis=1),
        "actions": jnp.concatenate([tr(outs["actions"]), zeros_a], axis=1),
        "behavior_logp": jnp.concatenate([tr(outs["logp"]), zeros_l],
                                         axis=1),
        "behavior_value": jnp.concatenate(
            [tr(outs["value"]), jnp.zeros((b, 1))], axis=1),
        "rewards": tr(outs["reward"]),
        "dones": tr(outs["done"]),
        "steps": steps.astype(jnp.int32),
        "mask": jnp.ones((b, wm.imagine_horizon), jnp.float32),
    }


def make_imagine_fn(cfg: ModelConfig, wm: WMConfig):
    def fn(policy_params, obs_params, reward_params, key, tokens, frame0,
           step0):
        return imagine_rollout(policy_params, obs_params, reward_params,
                               key, tokens, frame0, step0, cfg=cfg, wm=wm)
    return jax.jit(fn)


def imagine_segment(*args, **kwargs):
    """Alias kept for the public API (one τ̂ segment per call)."""
    return imagine_rollout(*args, **kwargs)


class ImaginationWorker(Service):
    """Generates imagined segments from real seed frames in B_wm and pushes
    them to B_img — the WM-mode replacement for environment interaction.
    An imagination *producer service* registered on the bus by the
    world-model attachment."""

    def __init__(self, worker_id: int, cfg: ModelConfig, wm: WMConfig,
                 store, wm_params_ref, frame_channel, img_channel, *,
                 batch: int = 16, seed: int = 0):
        super().__init__(f"imagination-{worker_id}", role="imagination")
        self.cfg, self.wm = cfg, wm
        self.store = store                    # policy weight store
        self.wm_params_ref = wm_params_ref    # dict with obs/reward params
        self.frame_channel = frame_channel    # B_wm (real transitions)
        self.img_channel = img_channel        # B_img
        self.batch = batch
        self._fn = make_imagine_fn(cfg, wm)
        self._key = jax.random.PRNGKey(seed + 7777)

    @property
    def segments_done(self) -> int:
        return int(self.metrics.counter("segments"))

    @property
    def imagined_steps(self) -> int:
        return int(self.metrics.counter("imagined_steps"))

    def _run(self) -> None:
        params, version = None, -1
        while not self._stop.is_set():
            got = self.store.acquire(newer_than=-1, timeout=0.2)
            if got is None:
                continue
            params, version = got
            seeds = self.frame_channel.sample(self.batch)
            if seeds is None:
                time.sleep(0.05)
                continue
            tokens = np.stack([s["tokens"] for s in seeds])
            frames = np.stack([s["frame"] for s in seeds]).astype(np.float32)
            steps = np.array([s["step"] for s in seeds], np.int32)
            self._key, sub = jax.random.split(self._key)
            # the imagined batch's trace id: the policy version it was
            # dreamed under, so wm.imagine lines up with the
            # weights.publish flow on the Perfetto timeline
            with (_tel.span("wm.imagine", cat="wm", trace=int(version),
                            args={"batch": self.batch,
                                  "horizon": self.wm.imagine_horizon,
                                  "version": int(version)}, flow="step")
                  if _tel is not None else _NULL_CTX):
                with self.metrics.timer("busy_s"):
                    out = self._fn(params, self.wm_params_ref["obs"],
                                   self.wm_params_ref["reward"], sub, tokens,
                                   frames, steps)
                    out = {k: np.asarray(v) for k, v in out.items()}
            for i in range(self.batch):
                self.img_channel.put({
                    "obs_tokens": out["obs_tokens"][i],
                    "frames": out["frames"][i],
                    "actions": out["actions"][i],
                    "behavior_logp": out["behavior_logp"][i],
                    "behavior_value": out["behavior_value"][i],
                    "rewards": out["rewards"][i],
                    "dones": out["dones"][i],
                    "steps": out["steps"][i],
                    "mask": out["mask"][i],
                    "policy_version": np.int32(version),
                    "task_id": np.int32(0),
                    "success": np.float32(0.0),
                })
            self.metrics.inc("segments", self.batch)
            self.metrics.inc("imagined_steps",
                             self.batch * self.wm.imagine_horizon)
