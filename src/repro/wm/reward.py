"""M_reward — the "virtual referee" (paper §4): a binary success classifier
over (stacked) frames, regressed on real (o_t, success_t) pairs from B_wm
every ``reward_train_interval`` steps. Its success probability drives both
the potential-based imagined reward (eq. 4) and the imagined termination
signal."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


def reward_init(key, frame_dim: int, hidden: int = 128) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (frame_dim, hidden), jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": dense_init(k2, (hidden, hidden), jnp.float32),
        "b2": jnp.zeros((hidden,), jnp.float32),
        "w3": dense_init(k3, (hidden, 1), jnp.float32),
        "b3": jnp.zeros((1,), jnp.float32),
    }


def reward_logit(params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(frames @ params["w1"] + params["b1"])
    h = jax.nn.silu(h @ params["w2"] + params["b2"])
    return (h @ params["w3"] + params["b3"])[..., 0]


def reward_apply(params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    """Success probability M_reward(o) ∈ (0, 1). frames: [B, F] -> [B]."""
    return jax.nn.sigmoid(reward_logit(params, frames))


def reward_loss(params: Params, frames: jnp.ndarray,
                success: jnp.ndarray) -> jnp.ndarray:
    """Binary cross-entropy on real success labels."""
    logit = reward_logit(params, frames)
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * success
        + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def make_reward_train_step(lr: float = 1e-4):
    from repro.optim import adamw

    def step(params, opt, frames, success):
        loss, grads = jax.value_and_grad(reward_loss)(params, frames,
                                                      success)
        new_params, new_opt, _ = adamw.update(grads, opt, params,
                                              jnp.asarray(lr))
        return new_params, new_opt, loss
    return jax.jit(step)
