"""Policy-gradient objectives: GIPO (paper eqs. 5–6, 9) and the PPO baseline.

Token-level optimization (App. D.3): each action token is an independent
decision point; the importance ratio, trust weight and surrogate are all
computed per token, with the step advantage broadcast across the step's
action tokens. This avoids the vanishing-product instability of chunk-level
ratios and keeps gradient signal when single tokens go stale.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def gaussian_trust_weight(log_ratio_sg: jnp.ndarray,
                          sigma: float) -> jnp.ndarray:
    """ω(ρ̄; σ) = exp(−½ (log ρ̄ / σ)²)   (eq. 5). Input is stop-gradient
    log-ratio."""
    return jnp.exp(-0.5 * jnp.square(log_ratio_sg / sigma))


def gipo_loss(logp_new: jnp.ndarray, logp_old: jnp.ndarray,
              advantages: jnp.ndarray, mask: jnp.ndarray,
              sigma: float) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Token-level GIPO surrogate (eq. 6).

    logp_new/logp_old: [B, T, A]; advantages: [B, T] (broadcast over A);
    mask: [B, T]. Returns (scalar loss, metrics).
    """
    log_ratio = logp_new - logp_old                       # [B, T, A]
    ratio = jnp.exp(log_ratio)
    log_ratio_sg = jax.lax.stop_gradient(log_ratio)
    omega = gaussian_trust_weight(log_ratio_sg, sigma)
    adv = advantages[..., None]                           # [B, T, 1]
    per_token = -(omega * ratio * adv)                    # eq. 6
    m = mask[..., None]
    denom = jnp.maximum(m.sum() * per_token.shape[-1], 1.0)
    loss = jnp.sum(per_token * m) / denom
    metrics = {
        "ratio_mean": jnp.sum(ratio * m) / denom,
        "omega_mean": jnp.sum(omega * m) / denom,
        "stale_frac": jnp.sum((jnp.abs(log_ratio_sg) > 2 * sigma) * m) / denom,
    }
    return loss, metrics


def ppo_loss(logp_new: jnp.ndarray, logp_old: jnp.ndarray,
             advantages: jnp.ndarray, mask: jnp.ndarray,
             clip_eps: float) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Token-level PPO-clip baseline (the ablation's comparison point)."""
    ratio = jnp.exp(logp_new - logp_old)
    adv = advantages[..., None]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    per_token = -jnp.minimum(unclipped, clipped)
    m = mask[..., None]
    denom = jnp.maximum(m.sum() * per_token.shape[-1], 1.0)
    loss = jnp.sum(per_token * m) / denom
    clip_frac = jnp.sum((jnp.abs(ratio - 1.0) > clip_eps) * m) / denom
    return loss, {"ratio_mean": jnp.sum(ratio * m) / denom,
                  "clip_frac": clip_frac}


def kl_penalty(logp_new: jnp.ndarray, logp_old: jnp.ndarray,
               mask: jnp.ndarray) -> jnp.ndarray:
    """k3 estimator of KL(μ ‖ π): (ρ⁻¹ − 1) + log ρ ≥ 0, low variance."""
    log_ratio = logp_new - logp_old
    k3 = jnp.expm1(-log_ratio) + log_ratio
    m = mask[..., None]
    return jnp.sum(k3 * m) / jnp.maximum(m.sum() * k3.shape[-1], 1.0)


def entropy_bonus(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean policy entropy over valid action tokens. logits: [B, T, A, V]."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)         # [B, T, A]
    m = mask[..., None]
    return jnp.sum(ent * m) / jnp.maximum(m.sum() * ent.shape[-1], 1.0)


def value_loss(values: jnp.ndarray, targets: jnp.ndarray,
               mask: jnp.ndarray) -> jnp.ndarray:
    """0.5 (V − R)² over valid steps; targets are detached by the caller."""
    err = 0.5 * jnp.square(values - targets)
    return jnp.sum(err * mask) / jnp.maximum(mask.sum(), 1.0)
