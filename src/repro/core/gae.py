"""Generalized Advantage Estimation with just-in-time value recomputation.

Paper §5 + App. C.1: instead of a separate re-inference pass over the
dataset, GAE runs on the values produced by the *training* forward pass,
inside the micro-batch step. Because parameters are frozen within a
gradient-accumulation window (eq. 7), this is exactly equivalent to a
forced re-inference pass — ``tests/test_gae.py`` asserts the equivalence.

Segment layout (paper eq. 2): arrays carry T+1 entries; index T holds the
bootstrap observation o_{T+1}. Its value feeds GAE as the bootstrap target
only — it is detached from the graph and excluded from every loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def gae(values: jnp.ndarray, rewards: jnp.ndarray, dones: jnp.ndarray,
        discount: float, lam: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """values: [B, T+1] (index T = bootstrap ṽ_{T+1}, caller detaches);
    rewards, dones: [B, T]. Returns (advantages [B, T], returns [B, T]).

    ``dones`` marks *natural* termination after step t — the bootstrap is
    masked there (no value flows across episode boundaries).
    """
    t = rewards.shape[1]
    v_now = values[:, :t]
    v_next = values[:, 1:t + 1]
    nonterm = 1.0 - dones.astype(jnp.float32)
    deltas = rewards + discount * nonterm * v_next - v_now      # [B, T]

    def body(carry, xs):
        delta, nt = xs
        adv = delta + discount * lam * nt * carry
        return adv, adv

    _, advs = jax.lax.scan(
        body, jnp.zeros_like(deltas[:, 0]),
        (deltas.T, nonterm.T), reverse=True)
    advantages = advs.T                                          # [B, T]
    returns = advantages + v_now
    return advantages, returns


def gae_reference(values, rewards, dones, discount, lam):
    """Slow python-loop oracle for tests."""
    import numpy as np
    values = np.asarray(values, np.float64)
    rewards = np.asarray(rewards, np.float64)
    dones = np.asarray(dones, np.float64)
    b, t = rewards.shape
    adv = np.zeros((b, t))
    for i in range(b):
        acc = 0.0
        for j in reversed(range(t)):
            nonterm = 1.0 - dones[i, j]
            delta = rewards[i, j] + discount * nonterm * values[i, j + 1] \
                - values[i, j]
            acc = delta + discount * lam * nonterm * acc
            adv[i, j] = acc
    return adv, adv + values[:, :t]


def jit_gae_from_forward(values_with_bootstrap: jnp.ndarray,
                         rewards: jnp.ndarray, dones: jnp.ndarray,
                         discount: float, lam: float
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The paper's low-overhead pipeline: values come straight from the
    training forward pass; the bootstrap column is detached here (App. C.1
    'the target value node must be detached from the computation graph')."""
    values = jax.lax.stop_gradient(values_with_bootstrap)
    return gae(values, rewards, dones, discount, lam)
