"""The AcceRL trainer step: GIPO + just-in-time GAE + lagged normalization
with sequential micro-batch slicing and gradient accumulation (paper §5,
App. C).

Structure per optimizer step (one gradient-accumulation window):
  1. slice the batch *sequentially* into micro-batches (contiguous memory —
     the paper's replacement for global shuffling),
  2. per micro-batch: training forward → values → GAE on the spot (value
     recomputation without a second forward pass) → normalize with the
     PREVIOUS step's global stats (eq. 8) → GIPO/PPO loss → grads,
  3. accumulate grads and the packed (sum, sum², count) advantage stats,
  4. single optimizer update; fold the stats into the Welford running state
     (the deferred "synchronous aggregation at the end of backpropagation").

Under pjit the batch is sharded over ``data`` so the ``jnp.sum`` inside the
stats produces the paper's single all-reduce automatically; ``shard_map``
users can call ``advnorm.psum_stats`` explicitly.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RLConfig
from repro.core import advnorm, gae, gipo
from repro.core.advnorm import AdvNormState
from repro.data.trajectory import TrajectoryBatch
from repro.kernels import dispatch
from repro.models.policy import (
    action_log_prob,
    policy_forward,
    policy_forward_hidden,
)
from repro.optim import adamw


class TrainState(NamedTuple):
    params: dict
    opt: adamw.AdamWState
    adv_norm: AdvNormState
    version: jnp.ndarray            # i32 — published-policy version counter


def init_train_state(cfg: ModelConfig, key, *, mesh=None) -> TrainState:
    """Build the live trainer state.

    With ``mesh`` (any mesh carrying a ``data`` axis), the f32 Adam
    moments are materialized under ``optim.zero.shard_moments_spec`` —
    ZeRO-2: parameters stay replicated over ``data`` while each moment
    tensor's largest divisible axis is sharded over it (paper §3.1,
    "partition optimizer states ... supporting larger micro-batch
    sizes"). On a single-device mesh this is a no-op, so the wiring is
    unconditional in :class:`~repro.runtime.trainer.TrainerWorker`.
    """
    from repro.models.policy import init_policy_params
    params = init_policy_params(cfg, key)
    opt = adamw.init(params)
    if mesh is not None and getattr(mesh, "devices", None) is not None \
            and mesh.devices.size > 1:
        from repro.sharding import rules
        shapes = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
        pspec = rules.param_specs(cfg, shapes, mesh)
        from jax.sharding import NamedSharding
        params = jax.device_put(
            params, jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                                 is_leaf=lambda x: not isinstance(x, dict)))
        from repro.optim import zero
        opt = zero.shard_opt_state(opt, mesh, param_specs=pspec)
    return TrainState(params=params, opt=opt,
                      adv_norm=advnorm.init_adv_state(),
                      version=jnp.zeros((), jnp.int32))


def _score_batch(cfg: ModelConfig, params, micro: TrajectoryBatch, *,
                 remat: bool):
    """Teacher-forced scoring of every (obs, action) step incl. bootstrap.

    Returns (logits [b,T+1,A,V], values [b,T+1])."""
    b, tp1 = micro.obs_tokens.shape[:2]
    flat = lambda x: x.reshape((b * tp1,) + x.shape[2:])
    prefix = None
    if micro.prefix_embeds is not None:
        prefix = flat(micro.prefix_embeds)
    out = policy_forward(cfg, params, flat(micro.obs_tokens),
                         flat(micro.actions), flat(micro.steps),
                         prefix_embeds=prefix, remat=remat)
    logits = out.logits.reshape(b, tp1, *out.logits.shape[1:])
    values = out.value.reshape(b, tp1)
    return logits, values, out.aux


def _score_batch_hidden(cfg: ModelConfig, params, micro: TrajectoryBatch, *,
                        remat: bool):
    """Head-free twin of ``_score_batch`` for the fused-loss path.

    Returns (pred_hidden [b,T+1,A,d], values [b,T+1], aux)."""
    b, tp1 = micro.obs_tokens.shape[:2]
    flat = lambda x: x.reshape((b * tp1,) + x.shape[2:])
    prefix = None
    if micro.prefix_embeds is not None:
        prefix = flat(micro.prefix_embeds)
    out = policy_forward_hidden(cfg, params, flat(micro.obs_tokens),
                                flat(micro.actions), flat(micro.steps),
                                prefix_embeds=prefix, remat=remat)
    hidden = out.pred_hidden.reshape(b, tp1, *out.pred_hidden.shape[1:])
    values = out.value.reshape(b, tp1)
    return hidden, values, out.aux


def _gae_and_norm(values, micro: TrajectoryBatch, adv_state: AdvNormState,
                  rl: RLConfig):
    """Just-in-time GAE (value recomputation, App. C.1) + lagged norm.

    Ablation (Fig. 7): value_recompute=False falls back to the STALE
    values recorded at collection time — misaligned targets."""
    values_for_gae = values if rl.value_recompute else micro.behavior_value
    adv, returns = gae.jit_gae_from_forward(
        values_for_gae, micro.rewards, micro.dones, rl.discount,
        rl.gae_lambda)
    stats = advnorm.local_stats(adv, micro.mask)
    adv_n = advnorm.normalize_lagged(adv, adv_state)
    return jax.lax.stop_gradient(adv_n), returns, stats


def _assemble_loss(cfg: ModelConfig, rl: RLConfig, pg, v_loss, kl, ent,
                   aux, stats, pg_metrics):
    """Combine the loss terms and build the metrics dict — shared by the
    reference and fused paths so they cannot drift apart."""
    total = pg + rl.value_coef * v_loss + rl.kl_coef * kl \
        - rl.entropy_coef * ent
    if cfg.arch_type == "moe":
        total = total + aux["load_balance"] + aux["router_z"]
    metrics = {
        "loss": total, "pg_loss": pg, "value_loss": v_loss, "kl": kl,
        "entropy": ent, "adv_mean_raw": stats[0] / jnp.maximum(stats[2], 1.0),
        **pg_metrics,
    }
    if cfg.arch_type == "moe":
        metrics["moe_load_balance"] = aux["load_balance"]
        metrics["moe_dropped_frac"] = aux["dropped_frac"]
    return total, (metrics, stats)


def _fused_loss_fn(params, micro: TrajectoryBatch, adv_state: AdvNormState,
                   cfg: ModelConfig, rl: RLConfig, *, remat: bool
                   ) -> Tuple[jnp.ndarray, Tuple[Dict, jnp.ndarray]]:
    """Fused-loss path: the action head + GIPO/entropy/KL run block-fused
    on hidden states (kernels/dispatch.py) — the [b,T,A,Va] logit tensor
    and its log-softmax are never materialized. Exact parity (loss and
    grads) with the reference path is asserted in tests."""
    t = micro.horizon
    hidden, values, aux = _score_batch_hidden(cfg, params, micro,
                                              remat=remat)
    adv_n, returns, stats = _gae_and_norm(values, micro, adv_state, rl)

    b = hidden.shape[0]
    a_dim = micro.actions.shape[2]
    hid = hidden[:, :t].reshape(b * t * a_dim, -1)
    pg, ent, kl, pg_metrics = dispatch.policy_head_loss(
        hid, params["action_head"]["w"],
        micro.actions[:, :t].reshape(-1),
        micro.behavior_logp[:, :t].reshape(-1),
        jnp.broadcast_to(adv_n[..., None], (b, t, a_dim)).reshape(-1),
        jnp.broadcast_to(micro.mask[..., None], (b, t, a_dim)).reshape(-1),
        sigma=rl.gipo_sigma, mode=rl.kernel_dispatch)
    pg_metrics = jax.tree.map(jax.lax.stop_gradient, pg_metrics)

    v_loss = gipo.value_loss(values[:, :t], jax.lax.stop_gradient(returns),
                             micro.mask)
    return _assemble_loss(cfg, rl, pg, v_loss, kl, ent, aux, stats,
                          pg_metrics)


def loss_fn(params, micro: TrajectoryBatch, adv_state: AdvNormState,
            cfg: ModelConfig, rl: RLConfig, *, remat: bool = False
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if rl.fused_loss and rl.algo == "gipo":
        return _fused_loss_fn(params, micro, adv_state, cfg, rl,
                              remat=remat)
    t = micro.horizon
    logits, values, aux = _score_batch(cfg, params, micro, remat=remat)
    adv_n, returns, stats = _gae_and_norm(values, micro, adv_state, rl)

    # --- token-level policy loss (App. D.3) ----------------------------------
    logp_new = action_log_prob(logits[:, :t], micro.actions[:, :t])
    logp_old = micro.behavior_logp[:, :t]
    if rl.algo == "gipo":
        pg, pg_metrics = gipo.gipo_loss(logp_new, logp_old, adv_n,
                                        micro.mask, rl.gipo_sigma)
    else:
        pg, pg_metrics = gipo.ppo_loss(logp_new, logp_old, adv_n,
                                       micro.mask, rl.ppo_clip)

    # --- value loss: bootstrap column excluded ("loss forcibly set to 0") ---
    v_loss = gipo.value_loss(values[:, :t], jax.lax.stop_gradient(returns),
                             micro.mask)
    kl = gipo.kl_penalty(logp_new, logp_old, micro.mask)
    ent = gipo.entropy_bonus(logits[:, :t], micro.mask)
    return _assemble_loss(cfg, rl, pg, v_loss, kl, ent, aux, stats,
                          pg_metrics)


def _microbatches(batch: TrajectoryBatch, n_micro: int):
    """Sequential contiguous slicing along the batch axis (App. C.1)."""
    b = batch.obs_tokens.shape[0]
    mb = b // n_micro

    def slice_i(i):
        def sl(x):
            if x is None:
                return None
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        return jax.tree.map(sl, batch,
                            is_leaf=lambda v: v is None)
    return slice_i, mb


# --------------------------------------------------------------------------
# Stage functions. These ARE the training step: ``train_step`` composes
# them under one jit (the fused path), and runtime/pipeline_exec.py jits
# each one separately as a RUN instruction body — both paths execute the
# same math, so parity is structural rather than asserted-after-the-fact.
# --------------------------------------------------------------------------

def zero_grads_like(params):
    """Fresh f32 accumulator matching ``params`` (one per accumulation
    window — the pipeline FREEs it after the optimizer update)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def microbatch_grads(params, micro: TrajectoryBatch,
                     adv_state: AdvNormState, *, cfg: ModelConfig,
                     rl: RLConfig, remat: bool = False):
    """fwd_bwd stage: grads + (metrics, packed adv stats) for one
    micro-batch against frozen params (eq. 7)."""
    grad_fn = jax.grad(
        functools.partial(loss_fn, cfg=cfg, rl=rl, remat=remat),
        has_aux=True)
    return grad_fn(params, micro, adv_state)


def accumulate_grads(acc, grads, stats_acc, stats, n_micro: int):
    """grad_reduce stage: fold one micro-batch's grads into the f32
    accumulator (mean over the window) and sum the packed stats."""
    acc = jax.tree.map(
        lambda a, g: a + g.astype(jnp.float32) / n_micro, acc, grads)
    return acc, stats_acc + stats


def apply_update(state: TrainState, grads, stats, metrics, *,
                 rl: RLConfig) -> Tuple[TrainState, Dict]:
    """optim_update stage: AdamW with the per-head lr tree, then fold the
    deferred advantage stats (end-of-backprop aggregation, App. C.1)."""
    lr_p = adamw.warmup_schedule(rl.lr_policy, rl.warmup_steps)(state.opt.step)
    lr_v = adamw.warmup_schedule(rl.lr_value, rl.warmup_steps)(state.opt.step)
    lr_tree = _lr_tree(state.params, lr_p, lr_v)
    new_params, new_opt, gnorm = adamw.update(
        grads, state.opt, state.params, lr_tree,
        max_grad_norm=rl.max_grad_norm)

    new_adv = advnorm.welford_update(state.adv_norm, stats)
    metrics = dict(metrics)
    metrics["grad_norm"] = gnorm
    metrics["adv_count"] = new_adv.count
    new_state = TrainState(params=new_params, opt=new_opt, adv_norm=new_adv,
                           version=state.version + 1)
    return new_state, metrics


def train_step(state: TrainState, batch: TrajectoryBatch, *,
               cfg: ModelConfig, rl: RLConfig,
               remat: bool = False) -> Tuple[TrainState, Dict]:
    """One optimizer step = ``rl.grad_accum`` micro-batch passes."""
    n_micro = rl.grad_accum
    slice_i, _ = _microbatches(batch, n_micro)

    def body(carry, i):
        grads_acc, stats_acc = carry
        micro = slice_i(i)
        grads, (metrics, stats) = microbatch_grads(
            state.params, micro, state.adv_norm, cfg=cfg, rl=rl, remat=remat)
        grads_acc, stats_acc = accumulate_grads(grads_acc, grads, stats_acc,
                                                stats, n_micro)
        return (grads_acc, stats_acc), metrics

    (grads, stats), metrics = jax.lax.scan(
        body, (zero_grads_like(state.params), jnp.zeros((3,))),
        jnp.arange(n_micro))
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return apply_update(state, grads, stats, metrics, rl=rl)


def _lr_tree(params, lr_policy, lr_value):
    """Per-leaf learning rates: the value head trains 10× hotter (Table 3)."""
    def assign(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        return lr_value if "value_head" in keys else lr_policy
    return jax.tree_util.tree_map_with_path(assign, params)


def make_train_step(cfg: ModelConfig, rl: RLConfig, *, remat: bool = False,
                    donate: bool = True):
    """jit-compiled train step bound to a config."""
    fn = functools.partial(train_step, cfg=cfg, rl=rl, remat=remat)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())
