"""The paper's primary contribution: GIPO, just-in-time GAE value
recomputation, lagged global advantage normalization, the trainer step, and
dynamic weighted resampling. The asynchronous scheduler lives in
``repro.runtime``; this package holds the math."""
from repro.core import advnorm, gae, gipo, resampler, train_step  # noqa: F401
from repro.core.advnorm import AdvNormState, init_adv_state  # noqa: F401
from repro.core.resampler import DynamicWeightedResampler  # noqa: F401
from repro.core.train_step import (  # noqa: F401
    TrainState,
    init_train_state,
    loss_fn,
    make_train_step,
    train_step,
)
