"""Lagged global advantage normalization (paper eq. 8 + App. C.1/C.2).

The paper hides the all-reduce of advantage statistics behind
backpropagation: the *current* batch is normalized with the *previous*
optimizer step's global moving statistics; the current batch's local
(sum, sum², count) triple is aggregated with ONE packed collective at the
gradient-accumulation boundary and folded into a running Welford state.

``psum_stats`` is the collective (``jax.lax.psum`` of a packed (3,) vector
— the JAX-native twin of the paper's single ``dist.all_reduce``); under
GSPMD/jit outside shard_map, ``jnp.sum`` over the sharded batch produces
the same all-reduce, so both paths are provided.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdvNormState(NamedTuple):
    """Welford running state of the advantage distribution."""

    count: jnp.ndarray   # f32 scalar
    mean: jnp.ndarray    # f32 scalar
    m2: jnp.ndarray      # f32 scalar (sum of squared deviations)

    @property
    def std(self) -> jnp.ndarray:
        var = jnp.where(self.count > 1, self.m2 / jnp.maximum(self.count, 1.0),
                        1.0)
        return jnp.sqrt(jnp.clip(var, 1e-12, None))


def init_adv_state() -> AdvNormState:
    return AdvNormState(count=jnp.zeros(()), mean=jnp.zeros(()),
                        m2=jnp.zeros(()))


def local_stats(adv: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Packed (sum, sum², count) — the single tensor that gets all-reduced."""
    s = jnp.sum(adv * mask)
    sq = jnp.sum(jnp.square(adv) * mask)
    n = jnp.sum(mask)
    return jnp.stack([s, sq, n])


def psum_stats(stats: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """One packed collective across data shards (inside shard_map/pmap)."""
    return jax.lax.psum(stats, axis_name)


def welford_update(state: AdvNormState,
                   global_stats: jnp.ndarray) -> AdvNormState:
    """Chan's parallel Welford merge of a batch (from its packed stats)."""
    s, sq, n = global_stats[0], global_stats[1], global_stats[2]
    n = jnp.maximum(n, 1e-9)
    batch_mean = s / n
    batch_m2 = sq - n * jnp.square(batch_mean)

    total = state.count + n
    delta = batch_mean - state.mean
    new_mean = state.mean + delta * n / total
    new_m2 = state.m2 + batch_m2 + jnp.square(delta) * state.count * n / total
    return AdvNormState(count=total, mean=new_mean, m2=new_m2)


def normalize_lagged(adv: jnp.ndarray, state: AdvNormState,
                     eps: float = 1e-8) -> jnp.ndarray:
    """Â_t = (A_t − μ_{t−1}) / (σ_{t−1} + ε)   (eq. 8). On the very first
    step (count == 0) the advantages pass through unnormalized."""
    has_stats = state.count > 0
    mean = jnp.where(has_stats, state.mean, 0.0)
    std = jnp.where(has_stats, state.std, 1.0)
    return (adv - mean) / (std + eps)


def normalize_batch(adv: jnp.ndarray, mask: jnp.ndarray,
                    eps: float = 1e-8) -> jnp.ndarray:
    """Synchronous (non-lagged) global normalization — the App. C.2
    pseudo-code, used as the baseline in the value-recompute benchmark."""
    stats = local_stats(adv, mask)
    n = jnp.maximum(stats[2], 1.0)
    mean = stats[0] / n
    var = jnp.clip(stats[1] / n - jnp.square(mean), 0.0, None)
    return (adv - mean) / (jnp.sqrt(var) + eps)
