"""Dynamic Weighted Resampling (paper App. D.4).

Host-side task sampler: a circular success-history window per task; the
sampling weight is the Laplace-smoothed recent failure rate, so compute is
steered toward lagging tasks while ``eps`` keeps every task alive
(anti-forgetting).
"""
from __future__ import annotations

import threading

import numpy as np


class DynamicWeightedResampler:
    def __init__(self, num_tasks: int, window_size: int = 100,
                 eps: float = 1.0, seed: int = 0):
        self.num_tasks = num_tasks
        self.window_size = window_size
        self.eps = eps
        # Initialized to ones to prevent early bias against unattempted tasks.
        self.history = np.ones((num_tasks, window_size))
        self.ptr = np.zeros(num_tasks, dtype=np.int64)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()

    def update_history(self, task_idx: int, success_flag: float) -> None:
        with self._lock:
            self.history[task_idx, self.ptr[task_idx]] = success_flag
            self.ptr[task_idx] = (self.ptr[task_idx] + 1) % self.window_size

    def probabilities(self) -> np.ndarray:
        with self._lock:
            success_counts = self.history.sum(axis=1)
        failure_counts = self.window_size - success_counts
        weights = failure_counts + self.eps
        return weights / weights.sum()

    def sample_task(self) -> int:
        return int(self._rng.choice(self.num_tasks, p=self.probabilities()))
