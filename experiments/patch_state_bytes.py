"""Back-fill ``state_bytes_per_dev`` (analytic params+cache residency) into
existing dry-run records — no recompilation needed.

    PYTHONPATH=src python experiments/patch_state_bytes.py [mesh ...]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import pathlib
import sys

from repro.configs import get_config, get_shape
from repro.launch import steps
from repro.launch.dryrun import _sharded_bytes
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import FSDP_PARAM_THRESHOLD

HERE = pathlib.Path(__file__).resolve().parent

for mesh_tag in (sys.argv[1:] or ["16x16", "2x16x16"]):
    d = HERE / "dryrun" / mesh_tag
    if not d.exists():
        continue
    mesh = make_production_mesh(multi_pod=mesh_tag == "2x16x16")
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if "error" in rec or rec.get("kind") == "train":
            continue
        cfg = get_config(rec["arch"])
        shape = get_shape(rec["shape"])
        fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD
        builder = (steps.prefill_specs if shape.kind == "prefill"
                   else steps.serve_specs)
        with mesh:
            sp = builder(cfg, shape, mesh, fsdp=fsdp)
        rec["state_bytes_per_dev"] = (
            _sharded_bytes(sp["params"], sp["shardings"]["params"])
            + _sharded_bytes(sp["cache"], sp["shardings"]["cache"]))
        f.write_text(json.dumps(rec, indent=1))
        print(f"{mesh_tag} {rec['arch']} {rec['shape']}: "
              f"state {rec['state_bytes_per_dev']/2**30:.2f} GiB")
